"""Fixed-capacity owner routing: the mesh frontier-exchange layer (§V-D).

FlexiWalker and ThunderRW both land on the same multi-GPU shape: route
*walkers to the shard that owns their frontier vertex* instead of
broadcasting walker state.  This module is that routing layer for a JAX
device mesh, built from three fixed-shape array programs so the whole
exchange traces into the sharded drain's ``lax.scan``:

- :class:`ShardQueue` + :func:`queue_push` / :func:`queue_pop` — one
  front-packed frontier queue per device (the single-partition counterpart
  of ``core.frontier.FrontierQueues``), generic over an entry's *fields*
  (vertex, instance, depth, prev, and any carried transition state such as
  the previous vertex's neighbor row).
- :func:`route_by_owner` — bucket a batch of live entries by destination
  shard with the cumsum owner-compaction machinery from ``core.frontier``
  (:func:`repro.core.frontier.owner_compaction`), compacting each
  destination's entries into a fixed ``(D, slots)`` send buffer.  Entries
  past a destination's ``slots`` are NOT dropped: they come back as a
  front-packed *leftover* batch the caller re-offers next round (the
  deferred-emigrant drain policy, DESIGN.md §12).
- :func:`all_to_all_fields` — the one collective: a tiled
  ``lax.all_to_all`` per field inside ``shard_map`` (row ``p`` of the
  result is the batch device ``p`` addressed to us).

Everything is gathers + one stable sort per call — no scatters (serialized
on CPU XLA), mirroring the §V frontier-queue implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.frontier import owner_compaction

#: fill value of empty slots in every int32 entry field
EMPTY = -1


def entry_nbytes(widths: Sequence[int]) -> int:
    """Wire footprint of ONE queue entry, in bytes.

    ``widths`` is the queue's field-width tuple (``0`` = scalar lane,
    ``K > 0`` = a ``(cap, K)`` payload lane such as a carried neighbor
    row); every lane is int32.  The sharded drain multiplies this by its
    exchanged-entry count to report ``exchange_bytes`` — the transfer-volume
    metric C-SAW's §V argument (and the BENCH flatness gate) is about.
    """
    return 4 * sum(max(int(w), 1) for w in widths)


def _fill_like(arr: jax.Array) -> jax.Array:
    return jnp.full((), EMPTY, arr.dtype)


def _masked(mask: jax.Array, vals: jax.Array) -> jax.Array:
    """Broadcast a slot mask over a field's trailing payload dims."""
    m = mask.reshape(mask.shape + (1,) * (vals.ndim - mask.ndim))
    return jnp.where(m, vals, _fill_like(vals))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardQueue:
    """One device's frontier queue: front-packed fixed-capacity field arrays.

    ``fields``: tuple of ``(cap,)`` or ``(cap, K)`` arrays — one per entry
    field, all front-packed together (``-1`` = empty slot).  By convention
    field 1 is the instance id, whose non-negativity marks a live entry.
    ``count``: ``()`` live entries; ``dropped``: ``()`` entries lost to
    capacity overflow on push (zero whenever ``cap`` covers the live walker
    population — the sharded walk sizes it so, DESIGN.md §12).
    """

    fields: Tuple[jax.Array, ...]
    count: jax.Array
    dropped: jax.Array

    def tree_flatten(self):
        return (self.fields, self.count, self.dropped), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.fields[0].shape[0]


def make_queue(capacity: int, widths: Sequence[int]) -> ShardQueue:
    """Allocate an empty queue; ``widths[i]`` > 0 adds a payload dim."""
    fields = tuple(
        jnp.full((capacity, w) if w > 0 else (capacity,), EMPTY, jnp.int32)
        for w in widths
    )
    return ShardQueue(fields, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def queue_push(
    q: ShardQueue, entries: Tuple[jax.Array, ...], valid: jax.Array
) -> ShardQueue:
    """Append ``valid`` entries (batch ``(N, ...)`` per field) at the tail.

    One stable sort front-packs the valid entries in batch order; placement
    is gathers only.  Entries past ``cap`` are dropped and counted.
    """
    cap = q.capacity
    n = valid.shape[0]
    order = jnp.argsort(jnp.where(valid, 0, 1))  # valid first, batch order
    nvalid = jnp.sum(valid.astype(jnp.int32))
    j = jnp.arange(cap, dtype=jnp.int32) - q.count  # incoming rank per slot
    fill = (j >= 0) & (j < nvalid)
    src = order[jnp.clip(j, 0, max(n - 1, 0))]
    new_fields = tuple(
        jnp.where(
            fill.reshape((cap,) + (1,) * (f.ndim - 1)), e[src], f
        )
        for f, e in zip(q.fields, entries)
    )
    new_count = jnp.minimum(q.count + nvalid, cap)
    dropped = q.dropped + nvalid - (new_count - q.count)
    return ShardQueue(new_fields, new_count, dropped)


def queue_pop(q: ShardQueue, n: int, limit: jax.Array | None = None):
    """Pop up to ``n`` entries off the (front-packed) queue head.

    Returns ``(entries, taken, q')`` with static ``(n, ...)`` entry shapes
    padded by ``-1``; ``limit`` (dynamic) caps the take without changing
    shapes.  Because the queue is always front-packed, the take is a plain
    prefix and the survivors a masked roll — no compaction sort needed.
    """
    cap = q.capacity
    if n > cap:
        raise ValueError(f"pop width {n} exceeds queue capacity {cap}")
    take = jnp.minimum(q.count, n)
    if limit is not None:
        take = jnp.minimum(take, jnp.maximum(limit, 0))
    out_mask = jnp.arange(n, dtype=jnp.int32) < take
    keep = q.count - take
    keep_mask = jnp.arange(cap, dtype=jnp.int32) < keep
    entries = tuple(_masked(out_mask, f[:n]) for f in q.fields)
    new_fields = tuple(
        _masked(keep_mask, jnp.roll(f, -take, axis=0)) for f in q.fields
    )
    return entries, take, ShardQueue(new_fields, keep, q.dropped)


def route_by_owner(
    entries: Tuple[jax.Array, ...],
    dest: jax.Array,
    valid: jax.Array,
    num_dest: int,
    slots: int,
):
    """Compact a batch of entries into per-destination send buffers.

    ``entries``: ``(N, ...)`` field arrays; ``dest``: ``(N,)`` destination
    shard of each entry; ``valid``: live mask.  Returns
    ``(send, sent, leftover, left_count)``:

    - ``send``: per-field ``(num_dest, slots, ...)`` buffers, row ``p``
      front-packed with the first ``slots`` entries addressed to shard
      ``p`` (batch order — older deferred entries keep priority when the
      caller concatenates them first);
    - ``sent``: ``(num_dest,)`` realized counts;
    - ``leftover``: per-field ``(N, ...)`` front-packed batch of the valid
      entries that did NOT fit their destination's slots this round —
      deferred, not dropped;
    - ``left_count``: ``()`` number of leftover entries.

    Built on :func:`repro.core.frontier.owner_compaction` — one stable sort
    groups entries per destination, cumsums assign within-group ranks, and
    every placement is a gather.
    """
    n = valid.shape[0]
    order, adds, offset = owner_compaction(dest, valid, num_dest)
    sent = jnp.minimum(adds, slots)
    j = jnp.arange(slots, dtype=jnp.int32)
    fill = j[None, :] < sent[:, None]  # (num_dest, slots)
    src = order[jnp.clip(offset[:, None] + j[None, :], 0, max(n - 1, 0))]
    send = tuple(_masked(fill, f[src]) for f in entries)

    # within-destination rank of each entry: its sorted position minus its
    # group's start — entries ranked past `slots` defer to the next round
    inv = jnp.argsort(order)  # original index -> sorted position
    rank = inv - offset[jnp.clip(dest, 0, num_dest - 1)]
    overflow = valid & (rank >= slots)
    left_count = jnp.sum(overflow.astype(jnp.int32))
    order2 = jnp.argsort(jnp.where(overflow, 0, 1))  # overflow first
    left_mask = jnp.arange(n, dtype=jnp.int32) < left_count
    leftover = tuple(_masked(left_mask, f[order2]) for f in entries)
    return send, sent, leftover, left_count


def all_to_all_fields(
    send: Tuple[jax.Array, ...], axis: str
) -> Tuple[jax.Array, ...]:
    """Exchange ``(D, slots, ...)`` send buffers over mesh axis ``axis``.

    Must run inside ``shard_map`` (it is the drain's one collective).  Row
    ``p`` of each returned buffer is the batch device ``p`` addressed to
    the calling device.
    """
    return tuple(
        jax.lax.all_to_all(f, axis, split_axis=0, concat_axis=0, tiled=True)
        for f in send
    )
