"""Pallas TPU kernel: O(1) alias-table walk step (adaptive selection runtime).

One grid step advances one walker by a single alias draw: the walker's CSR
segment blocks of the *prebuilt* per-row alias tables (``prob``/``alias``
from ``core.select.build_alias``) arrive by the same scalar-prefetch-driven
2-block DMA as the ITS walk kernel, then the draw is two one-hot gathers —
no cumsum, no O(degree) scan.  This is the static-bias (FlatBias) fast path
the cost model picks when a graph's tables are prebuilt and reused
(DESIGN.md §13); the serving service amortizes construction across requests.

Bit-parity contract: the kernel performs exactly the arithmetic of
``core.select.alias_draw_flat`` with ``cap = max_seg`` (f32 one-hot gathers
are exact — a single nonzero term — and vertex ids stay below 2^24), so
reference and Pallas backends agree bit-for-bit, including the truncation
semantics for oversized rows absorbed into the top bucket.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.its_select import resolve_interpret


def _alias_step_kernel(
    starts_ref,  # scalar-prefetch (W,)
    degs_ref,  # scalar-prefetch (W,)
    rand_ref,  # (1,) this walker's uniform (same stream an ITS cohort uses)
    p_lo_ref,  # (max_seg,) acceptance-threshold block containing `start`
    p_hi_ref,  # (max_seg,) following block
    a_lo_ref,  # (max_seg,) alias-offset blocks (row-local redirects)
    a_hi_ref,
    idx_lo_ref,  # (max_seg,) neighbor-id blocks
    idx_hi_ref,
    out_ref,  # (1,) next vertex
    *,
    max_seg: int,
):
    w = pl.program_id(0)
    start = starts_ref[w]
    deg = degs_ref[w]
    deg_eff = jnp.minimum(deg, max_seg)  # absorbed oversized rows truncate
    local = start % max_seg  # offset inside the 2-block window
    offs = jax.lax.broadcasted_iota(jnp.int32, (2 * max_seg,), 0)
    u = rand_ref[0] * deg_eff.astype(jnp.float32)
    slot = jnp.minimum(u.astype(jnp.int32), jnp.maximum(deg_eff - 1, 0))
    frac = u - slot.astype(jnp.float32)
    oh = (offs == local + slot).astype(jnp.float32)
    pval = jnp.sum(oh * jnp.concatenate([p_lo_ref[...], p_hi_ref[...]]))
    aliases = jnp.concatenate([a_lo_ref[...], a_hi_ref[...]])
    aval = jnp.sum(oh * aliases.astype(jnp.float32)).astype(jnp.int32)
    chosen = jnp.where(frac < pval, slot, aval)
    chosen = jnp.clip(chosen, 0, jnp.maximum(deg_eff - 1, 0))
    ids = jnp.concatenate([idx_lo_ref[...], idx_hi_ref[...]])
    oh2 = (offs == local + chosen).astype(jnp.float32)
    nxt = jnp.sum(oh2 * ids.astype(jnp.float32)).astype(jnp.int32)
    dead = (deg <= 0) | (aval < 0)  # zero-total rows carry alias = -1
    out_ref[0] = jnp.where(dead, -1, nxt)


@functools.partial(jax.jit, static_argnames=("max_seg", "interpret"))
def alias_step_pallas(
    starts: jax.Array,
    degs: jax.Array,
    indices: jax.Array,
    prob: jax.Array,
    alias: jax.Array,
    rand: jax.Array,
    *,
    max_seg: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """One alias-table walk step for W walkers.

    starts/degs: (W,) int32 row offsets/degrees; indices/prob/alias: flat
    CSR-aligned arrays padded to the kernel geometry (``pad_csr_for_kernel``
    — pad values are never read for real rows); rand: (W,) uniforms.
    Returns next vertices (W,) int32 (-1 dead end).
    """
    w = starts.shape[0]
    e = indices.shape[0]
    assert e % max_seg == 0, "pad CSR edge arrays with pad_csr_for_kernel"
    assert prob.shape[0] == e and alias.shape[0] == e, (prob.shape, alias.shape, e)

    def lo_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg,)

    def hi_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg + 1,)

    def per_walker(i, starts_ref, degs_ref):
        return (i,)

    kernel = functools.partial(_alias_step_kernel, max_seg=max_seg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1,), per_walker),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
        ],
        out_specs=pl.BlockSpec((1,), per_walker),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(starts, degs, rand, prob, prob, alias, alias, indices, indices)
