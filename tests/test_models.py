"""Model components: attention exactness, recurrent equivalences, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import init_tree, rms_norm, rope

KEY = jax.random.PRNGKey(0)


def base_cfg(**kw) -> ModelConfig:
    d = dict(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", attn_chunk=16, remat="none",
    )
    d.update(kw)
    return ModelConfig(**d)


def ref_attention(q, k, v, scale, window=0):
    """Naive full attention oracle (GQA via repeat)."""
    b, s, h, dh = q.shape
    g = h // k.shape[2]
    k = np.repeat(k, g, axis=2)
    v = np.repeat(v, g, axis=2)
    scores = np.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = np.tril(np.ones((s, s), bool))
    if window:
        mask &= ~np.tril(np.ones((s, s), bool), -window)
    scores = np.where(mask[None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


class TestBlockedAttention:
    @pytest.mark.parametrize("s,chunk,window", [(32, 8, 0), (64, 16, 0), (64, 16, 24), (48, 12, 12), (33, 16, 0)])
    def test_matches_naive(self, s, chunk, window):
        cfg = base_cfg(attn_chunk=chunk, window_size=window)
        b, h, kvh, dh = 2, 4, 2, 16
        q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, kvh, dh))
        out = attn.blocked_attention(q, k, v, cfg, window=window)
        ref = ref_attention(np.asarray(q), np.asarray(k), np.asarray(v), dh**-0.5, window)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)

    def test_decode_matches_train(self):
        """Token-by-token decode == full forward (the serving-correctness anchor)."""
        cfg = get_smoke_config("internlm2-1.8b")
        from repro.models import model as m
        params = m.init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
        full_logits, _ = m.forward(params, cfg, toks)
        cache = m.init_cache(cfg, 2, 16)
        outs = []
        for t in range(12):
            lg, cache = m.decode_step(params, cfg, toks[:, t : t + 1], cache)
            outs.append(np.asarray(lg[:, 0]))
        dec = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full_logits), dec, rtol=2e-3, atol=2e-3)

    def test_decode_matches_train_local_window(self):
        cfg = get_smoke_config("gemma3_1b")
        from repro.models import model as m
        params = m.init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
        full_logits, _ = m.forward(params, cfg, toks)
        cache = m.init_cache(cfg, 2, 32)
        outs = []
        for t in range(24):
            lg, cache = m.decode_step(params, cfg, toks[:, t : t + 1], cache)
            outs.append(np.asarray(lg[:, 0]))
        dec = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(full_logits), dec, rtol=3e-3, atol=3e-3)


class TestRope:
    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        dh = 32
        q = jax.random.normal(KEY, (1, 1, 1, dh))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, dh))
        def dot_at(m, n):
            qm = rope(q, jnp.array([[m]]), 10000.0)
            kn = rope(k, jnp.array([[n]]), 10000.0)
            return float(jnp.sum(qm * kn))
        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6

    def test_norm_preserved(self):
        x = jax.random.normal(KEY, (2, 8, 4, 32))
        y = rope(x, jnp.arange(8)[None], 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


class TestRecurrent:
    def test_rglru_train_decode_equivalence(self):
        cfg = base_cfg(rnn_width=64, conv1d_width=4)
        params = init_tree(KEY, rec.rglru_defs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 10, 64)) * 0.5
        y_train = rec.rglru_train(params, cfg, x)
        state = rec.rglru_init_state(cfg, 2, jnp.float32)
        ys = []
        for t in range(10):
            y, state = rec.rglru_decode(params, cfg, x[:, t : t + 1], state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.asarray(y_train), np.stack(ys, 1), rtol=1e-4, atol=1e-5)

    def test_mlstm_train_decode_equivalence(self):
        cfg = base_cfg(num_heads=2, mlstm_proj_factor=2.0, attn_chunk=5)
        params = init_tree(KEY, rec.mlstm_defs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 10, 64)) * 0.5
        y_train = rec.mlstm_train(params, cfg, x)
        state = rec.mlstm_init_state(cfg, 2, jnp.float32)
        ys = []
        for t in range(10):
            y, state = rec.mlstm_decode(params, cfg, x[:, t : t + 1], state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.asarray(y_train), np.stack(ys, 1), rtol=2e-3, atol=2e-4)

    def test_slstm_train_decode_equivalence(self):
        cfg = base_cfg(num_heads=4)
        params = init_tree(KEY, rec.slstm_defs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, 64)) * 0.5
        y_train = rec.slstm_train(params, cfg, x)
        state = rec.slstm_init_state(cfg, 2, jnp.float32)
        ys = []
        for t in range(8):
            y, state = rec.slstm_decode(params, cfg, x[:, t : t + 1], state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.asarray(y_train), np.stack(ys, 1), rtol=1e-4, atol=1e-5)

    def test_rglru_state_bounded(self):
        """|a| < 1 keeps the LRU state bounded over long rollouts."""
        cfg = base_cfg(rnn_width=64)
        params = init_tree(KEY, rec.rglru_defs(cfg), jnp.float32)
        x = jax.random.normal(KEY, (1, 500, 64))
        y = rec.rglru_train(params, cfg, x)
        assert np.isfinite(np.asarray(y)).all()
        assert np.abs(np.asarray(y)).max() < 1e3


class TestMoE:
    def test_output_finite_and_shape(self):
        cfg = base_cfg(num_experts=8, num_experts_per_tok=2)
        params = init_tree(KEY, moe_mod.moe_defs(cfg), jnp.float32)
        x = jax.random.normal(KEY, (2, 16, 64))
        y, aux = moe_mod.moe_apply(params, cfg, x)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert float(aux) > 0

    def test_capacity_drops_tokens(self):
        cfg = base_cfg(num_experts=4, num_experts_per_tok=1, capacity_factor=0.25)
        params = init_tree(KEY, moe_mod.moe_defs(cfg), jnp.float32)
        x = jax.random.normal(KEY, (1, 64, 64))
        y, _ = moe_mod.moe_apply(params, cfg, x)
        # some tokens dropped -> some rows ~0
        norms = np.linalg.norm(np.asarray(y[0]), axis=-1)
        assert (norms < 1e-6).any()

    def test_sampled_routing_differs_but_valid(self):
        cfg = base_cfg(num_experts=8, num_experts_per_tok=2, router_mode="sampled")
        params = init_tree(KEY, moe_mod.moe_defs(cfg), jnp.float32)
        x = jax.random.normal(KEY, (2, 16, 64))
        y1, _ = moe_mod.moe_apply(params, cfg, x, rng=jax.random.PRNGKey(1))
        y2, _ = moe_mod.moe_apply(params, cfg, x, rng=jax.random.PRNGKey(2))
        assert np.isfinite(np.asarray(y1)).all()
        assert not np.allclose(np.asarray(y1), np.asarray(y2))  # stochastic

    def test_sampled_routing_marginals(self):
        """C-SAW sampled routing: expert-selection frequency tracks router
        probabilities (Plackett-Luce first draw == softmax)."""
        cfg = base_cfg(num_experts=4, num_experts_per_tok=1, router_mode="sampled")
        params = init_tree(KEY, moe_mod.moe_defs(cfg), jnp.float32)
        x = jax.random.normal(KEY, (1, 8, 64))
        xt = x.reshape(-1, 64)
        gates, idx, probs = moe_mod._route(params, cfg, xt, jax.random.PRNGKey(0))
        # empirical over many rngs for token 0
        sel = []
        for i in range(800):
            _, idx, _ = moe_mod._route(params, cfg, xt[:1], jax.random.PRNGKey(i))
            sel.append(int(idx[0, 0]))
        counts = np.bincount(sel, minlength=4) / 800
        np.testing.assert_allclose(counts, np.asarray(probs[0]), atol=0.06)


class TestNorm:
    def test_rms_norm_unit_scale(self):
        x = jax.random.normal(KEY, (4, 32)) * 3.0
        y = rms_norm(x, jnp.zeros(32))
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_bf16_path_close_to_f32(self):
        x = jax.random.normal(KEY, (4, 256))
        y32 = rms_norm(x, jnp.zeros(256))
        y16 = rms_norm(x.astype(jnp.bfloat16), jnp.zeros(256, jnp.bfloat16))
        np.testing.assert_allclose(np.asarray(y16).astype(np.float32), np.asarray(y32), rtol=0.03, atol=0.03)
