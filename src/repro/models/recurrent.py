"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and xLSTM (mLSTM, sLSTM).

Time-parallel forms are used wherever they exist:
  - RG-LRU: ``jax.lax.associative_scan`` over (a, b) affine pairs.
  - mLSTM: blocked quadratic form with cumulative log-forget bias and an
    online max-stabilizer (same blocking scheme as attention — exact FLOPs,
    bounded transients; the TPU answer to the paper's chunkwise kernels).
  - sLSTM: true hidden-to-hidden nonlinearity → honest sequential
    ``lax.scan`` over time (no parallel form exists; noted in DESIGN.md).

Each block also provides a single-token decode step carrying O(1) state —
this is what makes ``long_500k`` cells feasible for ssm/hybrid archs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, ashard, causal_conv1d, model_divides, rp_einsum

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# RG-LRU (Griffin)
# ---------------------------------------------------------------------------


def rglru_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    k = cfg.conv1d_width
    return {
        "wx": ParamDef((d, w), ("embed", "rnn")),
        "wgate": ParamDef((d, w), ("embed", "rnn")),
        "conv_w": ParamDef((w, k), ("rnn", None), scale=0.5),
        "wa": ParamDef((w, w), ("rnn", None)),
        "ba": ParamDef((w,), (None,), init="zeros"),
        "wi": ParamDef((w, w), ("rnn", None)),
        "bi": ParamDef((w,), (None,), init="zeros"),
        "lam": ParamDef((w,), (None,), init="lru_lambda"),
        "wout": ParamDef((w, d), ("rnn", "embed")),
    }


def _rglru_gates(params, u):
    c = 8.0
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, params["wa"]) + params["ba"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, params["wi"]) + params["bi"])
    log_a = -c * jax.nn.softplus(params["lam"]).astype(jnp.float32) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def rglru_train(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    gate = jax.nn.gelu(ashard(jnp.einsum("bsd,dw->bsw", x, params["wgate"]), "batch", None, "model"))
    u = ashard(jnp.einsum("bsd,dw->bsw", x, params["wx"]), "batch", None, "model")
    u, _ = causal_conv1d(u, params["conv_w"])
    a, b = _rglru_gates(params, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return rp_einsum("bsw,wd->bsd", gate * h, params["wout"], cfg.reduce_dtype)


def rglru_decode(
    params: dict, cfg: ModelConfig, x: jax.Array, state: dict
) -> Tuple[jax.Array, dict]:
    """x: (B, 1, D); state: {'h': (B, W) f32, 'conv': (B, K-1, W)}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["wgate"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["wx"])
    u, conv_state = causal_conv1d(u, params["conv_w"], state["conv"])
    a, b = _rglru_gates(params, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = gate * h[:, None].astype(x.dtype)
    return jnp.einsum("bsw,wd->bsd", y, params["wout"]), {"h": h, "conv": conv_state}


def rglru_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    p = int(d * cfg.mlstm_proj_factor)
    k = cfg.conv1d_width
    return {
        "wup": ParamDef((d, p), ("embed", "mlp")),
        "wz": ParamDef((d, p), ("embed", "mlp")),
        "conv_w": ParamDef((p, k), ("mlp", None), scale=0.5),
        "wq": ParamDef((p, p), ("mlp", None)),
        "wk": ParamDef((p, p), ("mlp", None)),
        "wv": ParamDef((p, p), ("mlp", None)),
        "wif": ParamDef((p, 2 * cfg.num_heads), ("mlp", None), scale=0.1),
        "bif": ParamDef((2 * cfg.num_heads,), (None,), init="zeros"),
        "skip": ParamDef((p,), (None,), init="ones"),
        "wdown": ParamDef((p, d), ("mlp", "embed")),
    }


def _mlstm_qkv_gates(params, cfg, x):
    h = cfg.num_heads
    u = ashard(jnp.einsum("bsd,dp->bsp", x, params["wup"]), "batch", None, "model")
    z = ashard(jnp.einsum("bsd,dp->bsp", x, params["wz"]), "batch", None, "model")
    uc, _ = causal_conv1d(u, params["conv_w"])
    uc = jax.nn.silu(uc)
    q = jnp.einsum("bsp,pr->bsr", uc, params["wq"])
    k = jnp.einsum("bsp,pr->bsr", uc, params["wk"])
    v = jnp.einsum("bsp,pr->bsr", u, params["wv"])
    gif = jnp.einsum("bsp,pg->bsg", uc, params["wif"]) + params["bif"]
    ig, fg = gif[..., :h].astype(jnp.float32), gif[..., h:].astype(jnp.float32)
    b, s, p = q.shape
    hd = p // h
    shp = (b, s, h, hd)
    return q.reshape(shp), k.reshape(shp), v.reshape(shp), ig, fg, z, uc


def mlstm_train(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Blocked parallel mLSTM. x: (B, S, D)."""
    q, k, v, ig, fg, z, uc = _mlstm_qkv_gates(params, cfg, x)
    b, s, h, hd = q.shape
    scale = hd**-0.5
    logf = jax.nn.log_sigmoid(fg)  # (B,S,H)
    big_f = jnp.cumsum(logf, axis=1)  # F_t = sum_{tau<=t} log f
    from repro.models.attention import pick_chunk

    c = pick_chunk(s, cfg.attn_chunk)
    n = s // c
    qg = q.reshape(b, n, c, h, hd)
    kg = k.reshape(b, n, c, h, hd)
    vg = v.reshape(b, n, c, h, hd)
    fq = big_f.reshape(b, n, c, h)
    fk = big_f.reshape(b, n, c, h)
    iq = ig.reshape(b, n, c, h)
    # xLSTM head counts (4) rarely divide the model axis: shard the q-chunk
    # dim of the quadratic form instead (sequence-block parallelism).
    heads_ok = model_divides(h)
    if heads_ok:
        shd_q = lambda t: ashard(t, "batch", None, "model", None)
        shd_s = lambda t: ashard(t, "batch", "model", None)
        shd_a = lambda t: ashard(t, "batch", "model", None, None)
    else:
        shd_q = lambda t: ashard(t, "batch", "model", None, None)
        shd_s = lambda t: ashard(t, "batch", None, "model")
        shd_a = lambda t: ashard(t, "batch", None, "model", None)

    outs = []
    for qi in range(n):
        m0 = shd_s(jnp.full((b, h, c), NEG_INF, jnp.float32))
        num0 = shd_a(jnp.zeros((b, h, c, hd), jnp.float32))
        den0 = shd_s(jnp.zeros((b, h, c), jnp.float32))
        q_blk, fq_blk = shd_q(qg[:, qi]), fq[:, qi]
        q_idx = qi * c + jnp.arange(c)

        def step(carry, xs):
            m, num, den = carry
            kc, vc, fkc, ikc, koff = xs
            # decay bias D_ij = F_i - F_j + i_j  (j <= i)
            dmat = (
                fq_blk.transpose(0, 2, 1)[..., :, None]
                - fkc.transpose(0, 2, 1)[..., None, :]
                + ikc.transpose(0, 2, 1)[..., None, :]
            )  # (B,H,Cq,Ckv)
            k_idx = koff + jnp.arange(c)
            msk = k_idx[None, :] <= q_idx[:, None]
            dmat = jnp.where(msk[None, None], dmat, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(dmat, axis=-1))
            w = jnp.exp(dmat - m_new[..., None])
            s_qk = jnp.einsum(
                "bqhd,bchd->bhqc", q_blk, kc, preferred_element_type=jnp.float32
            ) * scale
            sw = s_qk * w
            corr = jnp.exp(m - m_new)
            num = num * corr[..., None] + jnp.einsum(
                "bhqc,bchd->bhqd", sw.astype(vc.dtype), vc, preferred_element_type=jnp.float32
            )
            den = den * corr + jnp.sum(sw, axis=-1)
            return (m_new, num, den), None

        koffs = jnp.arange(qi + 1) * c
        (m, num, den), _ = jax.lax.scan(
            step,
            (m0, num0, den0),
            (
                kg[:, : qi + 1].swapaxes(0, 1),
                vg[:, : qi + 1].swapaxes(0, 1),
                fk[:, : qi + 1].swapaxes(0, 1),
                iq[:, : qi + 1].swapaxes(0, 1),
                koffs,
            ),
        )
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        outs.append(hout.transpose(0, 2, 1, 3))  # (B,C,H,hd)
    y = jnp.concatenate(outs, axis=1).reshape(b, s, h * hd).astype(x.dtype)
    y = y + params["skip"] * uc
    y = y * jax.nn.silu(z)
    return rp_einsum("bsp,pd->bsd", y, params["wdown"], cfg.reduce_dtype)


def mlstm_decode(params, cfg: ModelConfig, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """x: (B,1,D); state: {'C': (B,H,hd,hd), 'n': (B,H,hd), 'm': (B,H), 'conv': ...}."""
    hn = cfg.num_heads
    u = jnp.einsum("bsd,dp->bsp", x, params["wup"])
    z = jnp.einsum("bsd,dp->bsp", x, params["wz"])
    uc, conv_state = causal_conv1d(u, params["conv_w"], state["conv"])
    uc = jax.nn.silu(uc)
    q = jnp.einsum("bsp,pr->bsr", uc, params["wq"])
    k = jnp.einsum("bsp,pr->bsr", uc, params["wk"])
    v = jnp.einsum("bsp,pr->bsr", u, params["wv"])
    gif = jnp.einsum("bsp,pg->bsg", uc, params["wif"]) + params["bif"]
    ig, fg = gif[..., :hn].astype(jnp.float32), gif[..., hn:].astype(jnp.float32)
    b = x.shape[0]
    hd = q.shape[-1] // hn
    q, k, v = (t.reshape(b, hn, hd) for t in (q[:, 0], k[:, 0], v[:, 0]))
    scale = hd**-0.5
    logf = jax.nn.log_sigmoid(fg[:, 0])  # (B,H)
    m_new = jnp.maximum(logf + state["m"], ig[:, 0])
    f_s = jnp.exp(logf + state["m"] - m_new)
    i_s = jnp.exp(ig[:, 0] - m_new)
    kf = k.astype(jnp.float32) * scale
    cmat = f_s[..., None, None] * state["C"] + i_s[..., None, None] * (
        vf := v.astype(jnp.float32)
    )[..., :, None] * kf[..., None, :]
    nvec = f_s[..., None] * state["n"] + i_s[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", cmat, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", nvec, qf)), jnp.exp(-m_new))
    hout = (num / den[..., None]).reshape(b, 1, hn * hd).astype(x.dtype)
    y = hout + params["skip"] * uc
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsp,pd->bsd", y, params["wdown"]), {
        "C": cmat, "n": nvec, "m": m_new, "conv": conv_state,
    }


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    p = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    hd = p // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e9, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, p), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block) — sequential by construction
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    up = int(d * cfg.slstm_proj_factor)
    return {
        "wx": ParamDef((d, 4 * d), ("embed", "mlp"), scale=0.5),
        "bx": ParamDef((4 * d,), (None,), init="zeros"),
        "r": ParamDef((h, hd, 4 * hd), (None, None, None), scale=0.5),
        "wup": ParamDef((d, up), ("embed", "mlp")),
        "wgate": ParamDef((d, up), ("embed", "mlp")),
        "wdown": ParamDef((up, d), ("mlp", "embed")),
    }


def _slstm_cell(params, cfg, xt, state):
    """One sLSTM step. xt: (B, 4D) pre-activations; state dicts are f32."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    b = xt.shape[0]
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    # recurrent contribution (block-diagonal per head); bf16 reduce_dtype
    # halves the per-step dR partial-sum all-reduce under pure DP
    # (EXPERIMENTS.md §Perf xlstm it.4)
    rec = rp_einsum(
        "bhk,hkg->bhg",
        h.reshape(b, nh, hd).astype(jnp.bfloat16 if cfg.reduce_dtype == "bf16" else h.dtype),
        params["r"].astype(jnp.bfloat16 if cfg.reduce_dtype == "bf16" else params["r"].dtype),
        cfg.reduce_dtype,
    ).reshape(b, 4 * cfg.d_model)
    z, i, f, o = jnp.split(xt.astype(jnp.float32) + rec.astype(jnp.float32), 4, axis=-1)
    m_new = jnp.maximum(f + m, i)  # exponential i, sigmoid-exp f stabilizer
    i_s = jnp.exp(i - m_new)
    f_s = jnp.exp(f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o) * (c_new / jnp.maximum(n_new, 1e-6))
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


SLSTM_TIME_CHUNK = 32


def slstm_train(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    xa = jnp.einsum("bsd,dg->bsg", x, params["wx"]) + params["bx"]
    state = slstm_init_state(cfg, b, x.dtype)

    # time-CHUNKED scan with an unrolled inner loop: the recurrent matrix
    # R is reused every step, and grad-of-scan makes GSPMD all-reduce dR
    # once per scan iteration (measured 12k × 4.3MB ARs = 53GB/step on
    # xlstm; EXPERIMENTS.md §Perf xlstm it.3).  Unrolling ``tc`` steps per
    # iteration accumulates dR locally and cuts that traffic by tc×.
    tc = SLSTM_TIME_CHUNK
    while s % tc:
        tc //= 2
    nch = s // tc

    def chunk(state, xc):  # xc: (tc, B, 4D)
        hs = []
        for t in range(tc):
            state = _slstm_cell(params, cfg, xc[t], state)
            hs.append(state["h"])
        return state, jnp.stack(hs)

    _, hs = jax.lax.scan(chunk, state, xa.swapaxes(0, 1).reshape(nch, tc, b, 4 * d))
    hs = hs.reshape(s, b, d).swapaxes(0, 1).astype(x.dtype)  # (B,S,D)
    # post up/gate/down MLP (xLSTM pf 4/3)
    up = jnp.einsum("bsd,du->bsu", hs, params["wup"])
    gate = jax.nn.gelu(jnp.einsum("bsd,du->bsu", hs, params["wgate"]))
    return jnp.einsum("bsu,ud->bsd", up * gate, params["wdown"])


def slstm_decode(params, cfg: ModelConfig, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    xa = jnp.einsum("bsd,dg->bsg", x, params["wx"]) + params["bx"]
    new = _slstm_cell(params, cfg, xa[:, 0], state)
    hs = new["h"][:, None].astype(x.dtype)
    up = jnp.einsum("bsd,du->bsu", hs, params["wup"])
    gate = jax.nn.gelu(jnp.einsum("bsd,du->bsu", hs, params["wgate"]))
    return jnp.einsum("bsu,ud->bsd", up * gate, params["wdown"]), new


def slstm_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32) * 1e-6,
        "m": jnp.zeros((batch, d), jnp.float32),
    }
