"""Batched multi-instance sampling service (paper §V-C, lifted to requests).

Front door for serving many concurrent, heterogeneous sampling requests:
admission-controlled queueing, padding-bucket batching keyed on lowered
transition programs, fused device launches, per-request results.  See
``docs/api.md`` for the public surface and ``benchmarks/bench_serve.py``
for the fused-vs-sequential throughput this layer buys.
"""
from repro.serve.queue import (
    AdmissionError,
    Cohort,
    RequestQueue,
    SamplingRequest,
    ServiceConfig,
    cohort_key,
)
from repro.serve.service import (
    DrainError,
    RequestResult,
    SamplingService,
    ServiceStats,
)

__all__ = [
    "AdmissionError",
    "DrainError",
    "Cohort",
    "RequestQueue",
    "RequestResult",
    "SamplingRequest",
    "SamplingService",
    "ServiceConfig",
    "ServiceStats",
    "cohort_key",
]
