"""Bias-based selection: ITS, BRS (Theorem 2), collision handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests are skipped without the [test] extra
    HAVE_HYPOTHESIS = False

from repro.core import select as sel


def chi2_stat(counts, probs):
    n = counts.sum()
    expected = probs * n
    keep = expected > 1e-9
    return float(np.sum((counts[keep] - expected[keep]) ** 2 / expected[keep]))


class TestWithReplacement:
    def test_matches_transition_probabilities(self):
        """Theorem 1: selection frequency ∝ bias."""
        biases = jnp.array([3.0, 6.0, 2.0, 2.0, 2.0])
        n = 40000
        idx = sel.select_with_replacement(
            jax.random.PRNGKey(0), jnp.tile(biases, (n, 1)), None, 1
        )[:, 0]
        counts = np.bincount(np.asarray(idx), minlength=5)
        probs = np.asarray(biases) / float(biases.sum())
        # chi-square with 4 dof: 99.9th percentile ~ 18.5
        assert chi2_stat(counts, probs) < 18.5

    def test_zero_bias_never_selected(self):
        biases = jnp.array([1.0, 0.0, 2.0, 0.0])
        idx = sel.select_with_replacement(
            jax.random.PRNGKey(1), jnp.tile(biases, (5000, 1)), None, 1
        )[:, 0]
        assert not np.isin(np.asarray(idx), [1, 3]).any()

    def test_masked_entries_never_selected(self):
        biases = jnp.ones((2000, 6))
        mask = jnp.tile(jnp.array([True, False, True, True, False, True]), (2000, 1))
        idx = sel.select_with_replacement(jax.random.PRNGKey(2), biases, mask, 2)
        assert not np.isin(np.asarray(idx), [1, 4]).any()


@pytest.mark.parametrize("method", ["its_brs", "repeated", "updated", "gumbel"])
class TestWithoutReplacement:
    def test_no_duplicates(self, method):
        key = jax.random.PRNGKey(3)
        biases = jax.random.uniform(key, (500, 16)) + 0.05
        res = sel.select_without_replacement(key, biases, None, 8, method=method)
        arr = np.asarray(res.indices)
        for row in arr:
            chosen = row[row >= 0]
            assert len(set(chosen.tolist())) == len(chosen)

    def test_all_valid_when_enough_candidates(self, method):
        key = jax.random.PRNGKey(4)
        biases = jax.random.uniform(key, (200, 32)) + 0.1
        res = sel.select_without_replacement(key, biases, None, 4, method=method)
        assert bool(res.valid.all())

    def test_insufficient_candidates_marked_invalid(self, method):
        biases = jnp.tile(jnp.array([1.0, 2.0, 0.0, 0.0]), (50, 1))
        res = sel.select_without_replacement(jax.random.PRNGKey(5), biases, None, 4, method=method)
        assert int(res.valid.sum(-1).max()) <= 2
        arr = np.asarray(res.indices)
        assert not np.isin(arr, [2, 3]).any()

    def test_first_draw_distribution(self, method):
        """First selection must follow the unmodified transition probs."""
        biases = jnp.array([5.0, 1.0, 1.0, 1.0, 2.0])
        n = 20000
        res = sel.select_without_replacement(
            jax.random.PRNGKey(6), jnp.tile(biases, (n, 1)), None, 3, method=method
        )
        first = np.asarray(res.indices[:, 0])
        counts = np.bincount(first[first >= 0], minlength=5)
        probs = np.asarray(biases) / float(biases.sum())
        assert chi2_stat(counts, probs) < 18.5


class TestBipartiteRegionSearch:
    @staticmethod
    def _set_counts(method, seed, biases, n=30000, k=2):
        res = sel.select_without_replacement(
            jax.random.PRNGKey(seed), jnp.tile(biases, (n, 1)), None, k, method=method
        )
        arr = np.sort(np.asarray(res.indices), axis=1)
        keys = arr[:, 0] * len(biases) + arr[:, 1]
        return np.bincount(keys, minlength=len(biases) ** 2)

    def test_repeated_equals_sequential_updated(self):
        """Identity: parallel draw + rejection-retry == sequential
        renormalized (Plackett-Luce) sampling.  (2·p_a·p_b + collision
        resolution mass algebraically equals p_a·p_b·(1/(1-p_a)+1/(1-p_b)).)"""
        biases = jnp.array([4.0, 3.0, 2.0, 1.0])
        rep = self._set_counts("repeated", 8, biases)
        upd = self._set_counts("updated", 9, biases)
        tot = rep + upd
        keep = tot > 0
        stat = np.sum((rep[keep] - upd[keep]) ** 2 / np.maximum(tot[keep], 1))
        assert stat < 25.0, (rep, upd)

    def test_brs_joint_bias_is_present_and_bounded(self):
        """FIDELITY FINDING (EXPERIMENTS.md §Fidelity): the paper's BRS
        reuses the *colliding* r, whose conditional law is uniform on the
        removed region — the transformed draw therefore concentrates on
        CTPS-adjacent candidates.  First-draw marginals stay exact (tested
        above), but the joint k-subset law deviates from Plackett-Luce.
        This test pins the deviation: present (so we notice if the
        implementation changes) and bounded (< 5pp on this pool)."""
        biases = jnp.array([4.0, 3.0, 2.0, 1.0])
        n = 30000
        brs = self._set_counts("its_brs", 7, biases, n) / n
        upd = self._set_counts("updated", 9, biases, n) / n
        dev = np.abs(brs - upd).max()
        assert 0.005 < dev < 0.05, dev

    def test_brs_fewer_iterations_than_repeated(self):
        """The paper's headline: BRS cuts retry iterations (Fig. 11)."""
        key = jax.random.PRNGKey(9)
        # skewed biases → high collision rate
        biases = jnp.tile(jnp.array([50.0, 1.0, 1.0, 1.0, 1.0, 1.0]), (2000, 1))
        brs = sel.select_without_replacement(key, biases, None, 4, method="its_brs")
        rep = sel.select_without_replacement(key, biases, None, 4, method="repeated")
        assert float(brs.iters.mean()) < float(rep.iters.mean())

    @staticmethod
    def _check_theorem2_transform(bias_list, seed):
        """Property check of the paper's Theorem 2: transforming a uniform r
        through BRS around a pre-selected region reproduces the *updated*
        CTPS distribution over the remaining candidates."""
        b = np.asarray(bias_list, dtype=np.float64)
        s = seed % len(b)  # pre-selected vertex
        rng = np.random.default_rng(seed)
        n = 4000
        r1 = rng.random(n)
        cum = np.cumsum(b) / b.sum()
        lower = np.concatenate([[0.0], cum[:-1]])
        l, h = lower[s], cum[s]
        delta = h - l
        r2 = r1 * (1.0 - delta)
        r2 = np.where(r2 < l, r2, r2 + delta)
        idx = np.searchsorted(cum, r2, side="right")
        idx = np.clip(idx, 0, len(b) - 1)
        assert not (idx == s).any()  # never re-selects the removed vertex
        # distribution over remaining == renormalized biases
        b2 = b.copy()
        b2[s] = 0.0
        probs = b2 / b2.sum()
        counts = np.bincount(idx, minlength=len(b)).astype(float)
        stat = chi2_stat(counts, probs)
        # generous bound: dof ≈ len(b)-2, 99.99th pct < 30 for <=12 bins
        assert stat < 40.0

    if HAVE_HYPOTHESIS:

        @settings(max_examples=30, deadline=None)
        @given(
            st.lists(st.floats(0.1, 20.0), min_size=3, max_size=12),
            st.integers(0, 2**31 - 1),
        )
        def test_theorem2_transform(self, bias_list, seed):
            self._check_theorem2_transform(bias_list, seed)

    else:

        def test_theorem2_transform(self):
            # single fixed example so the theorem still gets exercised
            # when the [test] extra (hypothesis) is absent
            self._check_theorem2_transform([4.0, 3.0, 2.0, 1.0, 0.5], 1234)


class TestChunkedTransition:
    def test_matches_padded_selection(self):
        from repro.graph import powerlaw_graph

        g = powerlaw_graph(256, seed=11, weighted=True)
        key = jax.random.PRNGKey(12)
        cur = jax.random.randint(key, (2000,), 0, 256)
        off = sel.walk_transition_chunked(key, g.indptr, g.weights, cur, chunk=8)
        off = np.asarray(off)
        deg = np.asarray(g.indptr[cur + 1] - g.indptr[cur])
        assert ((off >= 0) == (deg > 0)).all()
        assert (off[deg > 0] < deg[deg > 0]).all()

    def test_distribution(self):
        indptr = jnp.array([0, 4], dtype=jnp.int32)
        weights = jnp.array([1.0, 2.0, 3.0, 4.0])
        key = jax.random.PRNGKey(13)
        n = 20000
        off = sel.walk_transition_chunked(
            key, indptr, weights, jnp.zeros((n,), jnp.int32), chunk=2
        )
        counts = np.bincount(np.asarray(off), minlength=4)
        assert chi2_stat(counts, np.array([0.1, 0.2, 0.3, 0.4])) < 16.3
