"""Device-resident frontier queues (core.frontier, DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier


def _push(q, pid, v, inst=None, d=None, prev=None, valid=None):
    n = len(pid)
    pid = jnp.asarray(pid, jnp.int32)
    v = jnp.asarray(v, jnp.int32)
    inst = jnp.arange(n, dtype=jnp.int32) if inst is None else jnp.asarray(inst, jnp.int32)
    d = jnp.zeros(n, jnp.int32) if d is None else jnp.asarray(d, jnp.int32)
    prev = jnp.full((n,), -1, jnp.int32) if prev is None else jnp.asarray(prev, jnp.int32)
    valid = jnp.ones(n, bool) if valid is None else jnp.asarray(valid, bool)
    return frontier.push_many(q, pid, v, inst, d, prev, valid)


class TestPushMany:
    def test_cross_partition_scatter(self):
        """One vectorized push distributes a mixed batch to every owner."""
        q = frontier.make_queues(3, 8)
        q = _push(q, pid=[0, 2, 0, 1, 2], v=[10, 20, 30, 40, 50])
        np.testing.assert_array_equal(np.asarray(q.count), [2, 1, 2])
        np.testing.assert_array_equal(np.asarray(q.vertex[0][:2]), [10, 30])
        np.testing.assert_array_equal(np.asarray(q.vertex[1][:1]), [40])
        np.testing.assert_array_equal(np.asarray(q.vertex[2][:2]), [20, 50])
        np.testing.assert_array_equal(np.asarray(q.instance[0][:2]), [0, 2])
        assert int(q.dropped) == 0

    def test_appends_after_existing_tail(self):
        q = frontier.make_queues(2, 8)
        q = _push(q, pid=[0, 0], v=[1, 2])
        q = _push(q, pid=[0, 1], v=[3, 4])
        np.testing.assert_array_equal(np.asarray(q.vertex[0][:3]), [1, 2, 3])
        np.testing.assert_array_equal(np.asarray(q.count), [3, 1])

    def test_invalid_entries_not_pushed(self):
        q = frontier.make_queues(2, 8)
        q = _push(q, pid=[0, 0, 1], v=[1, 2, 3], valid=[True, False, True])
        np.testing.assert_array_equal(np.asarray(q.count), [1, 1])
        np.testing.assert_array_equal(np.asarray(q.vertex[0][:2]), [1, -1])

    def test_overflow_dropped_and_counted(self):
        q = frontier.make_queues(1, 4)
        q = _push(q, pid=[0] * 6, v=list(range(6)))
        np.testing.assert_array_equal(np.asarray(q.vertex[0]), [0, 1, 2, 3])
        assert int(q.count[0]) == 4
        assert int(q.dropped) == 2


class TestPopChunk:
    def test_fifo_and_compaction(self):
        q = frontier.make_queues(1, 8)
        q = _push(q, pid=[0] * 5, v=[10, 11, 12, 13, 14])
        (v, inst, d, prev), taken, q = frontier.pop_chunk(q, jnp.int32(0), 3)
        assert int(taken) == 3
        np.testing.assert_array_equal(np.asarray(v), [10, 11, 12])
        np.testing.assert_array_equal(np.asarray(inst), [0, 1, 2])
        # remainder left-compacted to the queue front
        np.testing.assert_array_equal(np.asarray(q.vertex[0][:3]), [13, 14, -1])
        assert int(q.count[0]) == 2

    def test_pop_pads_with_minus_one(self):
        q = frontier.make_queues(1, 8)
        q = _push(q, pid=[0], v=[7])
        (v, inst, d, prev), taken, q = frontier.pop_chunk(q, jnp.int32(0), 4)
        assert int(taken) == 1
        np.testing.assert_array_equal(np.asarray(v), [7, -1, -1, -1])
        np.testing.assert_array_equal(np.asarray(inst), [0, -1, -1, -1])
        assert int(q.count[0]) == 0

    def test_dynamic_limit(self):
        """The balance budget caps the take without changing shapes."""
        q = frontier.make_queues(1, 8)
        q = _push(q, pid=[0] * 5, v=list(range(5)))
        (v, *_), taken, q = frontier.pop_chunk(q, jnp.int32(0), 4, limit=jnp.int32(2))
        assert int(taken) == 2
        np.testing.assert_array_equal(np.asarray(v), [0, 1, -1, -1])
        assert int(q.count[0]) == 3

    def test_match_head_instance(self):
        """Fig. 13 per-instance baseline: only the front entry's instance."""
        q = frontier.make_queues(1, 8)
        q = _push(q, pid=[0] * 4, v=[1, 2, 3, 4], inst=[3, 3, 5, 3])
        (v, inst, *_), taken, q = frontier.pop_chunk(
            q, jnp.int32(0), 8, match_head_instance=True
        )
        assert int(taken) == 3
        np.testing.assert_array_equal(np.asarray(v[:3]), [1, 2, 4])
        np.testing.assert_array_equal(np.asarray(inst[:3]), [3, 3, 3])
        np.testing.assert_array_equal(np.asarray(q.instance[0][:2]), [5, -1])

    def test_pop_targets_one_partition(self):
        q = frontier.make_queues(3, 4)
        q = _push(q, pid=[0, 1, 2], v=[10, 20, 30])
        (v, *_), taken, q = frontier.pop_chunk(q, jnp.int32(1), 4)
        assert int(taken) == 1 and int(v[0]) == 20
        np.testing.assert_array_equal(np.asarray(q.count), [1, 0, 1])
        np.testing.assert_array_equal(np.asarray(q.vertex[0][:1]), [10])
        np.testing.assert_array_equal(np.asarray(q.vertex[2][:1]), [30])


class TestUnderJit:
    def test_roundtrip_inside_jit(self):
        """Both ops trace into a jitted drain-style program."""

        @jax.jit
        def roundtrip(q, pid):
            q = frontier.push_many(
                q,
                jnp.array([0, 1, 0], jnp.int32),
                jnp.array([5, 6, 7], jnp.int32),
                jnp.array([0, 1, 2], jnp.int32),
                jnp.zeros(3, jnp.int32),
                jnp.full((3,), -1, jnp.int32),
                jnp.ones(3, bool),
            )
            out, taken, q = frontier.pop_chunk(q, pid, 2)
            return out[0], taken, q.count

        v, taken, count = roundtrip(frontier.make_queues(2, 4), jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(v), [5, 7])
        assert int(taken) == 2
        np.testing.assert_array_equal(np.asarray(count), [0, 1])

    def test_queue_is_pytree(self):
        q = frontier.make_queues(2, 4)
        leaves, treedef = jax.tree_util.tree_flatten(q)
        assert len(leaves) == 6
        q2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(q2, frontier.FrontierQueues)
        assert q2.capacity == 4 and q2.num_partitions == 2
