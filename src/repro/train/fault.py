"""Fault tolerance: straggler detection, failure recovery, elastic re-mesh.

The trainer composes three mechanisms:

  1. ``StepMonitor`` — per-step wall-clock tracking; a step exceeding
     ``deadline_factor`` × median flags a straggler (on a real fleet this
     triggers hot-spare swap / collective re-formation; here it triggers an
     early checkpoint so the swap loses nothing).
  2. ``run_with_recovery`` — wraps the step; on failure restores the last
     checkpoint and replays (failures injected in tests).
  3. ``elastic_remesh`` — rebuilds the mesh from the currently visible
     device count and returns new shardings; CheckpointManager.restore with
     those shardings completes an elastic rescale (1000-node posture: node
     loss → shrink to the largest full (data, model) rectangle → continue).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh


class StepMonitor:
    def __init__(self, deadline_factor: float = 3.0, window: int = 50):
        self.deadline_factor = deadline_factor
        self.window = window
        self.durations: list[float] = []
        self.straggler_steps: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step duration; True = straggler (checkpoint now)."""
        self.durations.append(seconds)
        hist = self.durations[-self.window :]
        if len(hist) < 5:
            return False
        med = float(np.median(hist[:-1]))
        if seconds > self.deadline_factor * med:
            self.straggler_steps.append(step)
            return True
        return False

    @property
    def median(self) -> float:
        return float(np.median(self.durations)) if self.durations else 0.0


def run_with_recovery(
    step_fn: Callable,
    state,
    batch,
    *,
    restore_fn: Callable,
    max_retries: int = 2,
    fail_injector: Optional[Callable] = None,
):
    """Run one training step; on exception restore + retry.

    ``restore_fn()`` must return a fresh state (e.g. CheckpointManager
    restore).  ``fail_injector(attempt)`` raising simulates node failure.
    Returns (state, metrics, attempts_used).
    """
    for attempt in range(max_retries + 1):
        try:
            if fail_injector is not None:
                fail_injector(attempt)
            out = step_fn(*state, batch)
            return out[:-1], out[-1], attempt
        except Exception:
            if attempt == max_retries:
                raise
            state = restore_fn()
    raise RuntimeError("unreachable")


def largest_mesh_shape(n_devices: int, model_axis: int) -> tuple:
    """Largest (data, model) rectangle that fits n_devices, preserving the
    model axis (params must keep their TP layout to restore cheaply)."""
    model = model_axis
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model
    return (data, model)


def elastic_remesh(model_axis: int = 1) -> Mesh:
    """Build the best mesh from whatever devices are visible right now."""
    devs = np.array(jax.devices())
    data, model = largest_mesh_shape(len(devs), model_axis)
    return Mesh(devs[: data * model].reshape(data, model), ("data", "model"))
