"""musicgen-medium [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

Assigned: 48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.
Backbone only by assignment: the EnCodec frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (conditioning
prefix), projected and prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pattern=("global",),
    activation="gelu",
    glu=False,
    tie_embeddings=False,
    frontend="audio",
    frontend_tokens=256,
    optimizer="adamw",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pattern=("global",),
    activation="gelu",
    glu=False,
    tie_embeddings=False,
    frontend="audio",
    frontend_tokens=8,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
