"""Optimizers as pure pytree functions: AdamW and Adafactor.

No optax on this container — these are self-contained, sharding-friendly
implementations.  State trees mirror the param tree so param PartitionSpecs
apply verbatim (Adafactor's factored second moment uses reduced specs built
by dropping the factored dim — see launch/dryrun.py).
Adafactor (β1=0, factored v) is what lets the 400-480B MoE archs fit the
assigned pods: state is O(r+c) per matrix instead of O(r·c) (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128
    warmup_steps: int = 100


def _clip_scale(grads, max_norm):
    """Global-norm clip as a scalar factor — applied inside the per-leaf
    update so no scaled f32 copy of the full gradient tree materializes
    (7.5GB/device on the 480B archs)."""
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    norm = jnp.sqrt(g2)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9)), norm


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def _factored(p, min_dim):
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


_STATE_LEAF = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)


def opt_init(cfg: OptConfig, params):
    if cfg.kind == "adafactor":
        def init(p):
            if _factored(p, cfg.min_dim_factored):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"v": jax.tree_util.tree_map(init, params)}
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }


def opt_update(cfg: OptConfig, grads, state, params, step):
    """Returns (new_params, new_state, grad_norm)."""
    cscale, gnorm = _clip_scale(grads, cfg.grad_clip)
    t = step.astype(jnp.float32) + 1.0
    lr = _schedule(cfg, step)
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_g = [g.astype(jnp.float32) * cscale for g in flat_g]  # fused per leaf
    flat_p = jax.tree_util.tree_leaves(params)

    if cfg.kind == "adafactor":
        beta2 = 1.0 - t ** (-cfg.decay_rate)
        flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=_STATE_LEAF)[0]
        new_p, new_v = [], []
        for g, v, p in zip(flat_g, flat_v, flat_p):
            g2 = jnp.square(g) + 1e-30
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                precond = jax.lax.rsqrt(rfac[..., None] * vc[..., None, :] + 1e-30)
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                precond = jax.lax.rsqrt(vv + 1e-30)
                nv = {"v": vv}
            u = g * precond
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)  # Adafactor update clipping
            np_ = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
            new_p.append(np_.astype(p.dtype))
            new_v.append(nv)
        return (
            jax.tree_util.tree_unflatten(tdef, new_p),
            {"v": jax.tree_util.tree_unflatten(tdef, new_v)},
            gnorm,
        )

    # AdamW
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1**t)
        nu_hat = nu / (1 - cfg.b2**t)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)
    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(new_p), {"mu": unf(new_mu), "nu": unf(new_nu)}, gnorm


def opt_state_specs(cfg: OptConfig, param_specs, params_shape):
    """PartitionSpecs for optimizer state, derived from param specs.

    Adafactor factored leaves drop the corresponding dim of the param spec.
    ``params_shape``: pytree of ShapeDtypeStruct (to decide factoring).
    """
    from jax.sharding import PartitionSpec as P

    def pad(spec, ndim):
        parts = list(spec) + [None] * (ndim - len(spec))
        return parts

    if cfg.kind == "adafactor":
        def derive(spec, p):
            if _factored(p, cfg.min_dim_factored):
                parts = pad(spec, p.ndim)
                return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": spec}

        return {"v": jax.tree_util.tree_map(derive, param_specs, params_shape,
                                            is_leaf=lambda x: isinstance(x, P))}
    return {
        "mu": param_specs,
        "nu": param_specs,
    }
