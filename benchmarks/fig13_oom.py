"""Paper Figs. 13-15: out-of-memory sampling optimizations.

Configurations (cumulative, as in the paper):
  base   — per-instance processing, round-robin partitions, no balancing
  +BA    — batched multi-instance sampling (§V-C)
  +WS    — workload-aware partition scheduling (§V-B)
  +BAL   — thread-block workload balancing (proportional budgets)
Reported: wall time, kernel launches, partition transfers (Fig. 15) and
kernel workload std (Fig. 14).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BENCH_GRAPHS, row
from repro.core import algorithms as alg
from repro.core.oom import oom_random_walk
from repro.graph.partition import partition_by_vertex_range

CONFIGS = {
    "base": dict(batched=False, workload_aware=False, balance=False),
    "+BA": dict(batched=True, workload_aware=False, balance=False),
    "+BA+WS": dict(batched=True, workload_aware=True, balance=False),
    "+BA+WS+BAL": dict(batched=True, workload_aware=True, balance=True),
}


def run() -> list[str]:
    rows = []
    g = BENCH_GRAPHS["pl50k"]()
    md = min(g.max_degree(), 512)
    parts = partition_by_vertex_range(g, 8)
    seeds = np.random.default_rng(0).integers(0, g.num_vertices, 2000)
    key = jax.random.PRNGKey(2)
    base_time = None
    for cname, kw in CONFIGS.items():
        t0 = time.perf_counter()
        walks, stats = oom_random_walk(
            parts, g.num_vertices, seeds, key, depth=16,
            spec=alg.biased_random_walk(), max_degree=md,
            memory_capacity=2, num_streams=2, chunk=1024, **kw,
        )
        secs = time.perf_counter() - t0
        if base_time is None:
            base_time = secs
        rows.append(row(
            f"fig13/{cname}", secs * 1e6,
            f"speedup={base_time/secs:.2f}x;kernels={stats.kernel_launches};"
            f"transfers={stats.partition_transfers};ktime_std={stats.kernel_time_std():.1f};"
            f"SEPS={stats.sampled_edges/secs:.3e}",
        ))
    return rows
