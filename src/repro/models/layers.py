"""Base layers: param definitions, norms, embeddings, RoPE, causal conv.

Parameters are plain pytrees built from ``ParamDef`` specs so that a single
source of truth yields (a) initialized arrays, (b) ShapeDtypeStructs for the
dry-run, and (c) PartitionSpecs from logical axis names (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple  # logical axis names, same length as shape (None = replicated)
    init: str = "normal"  # normal | zeros | ones | lru_lambda
    scale: float = 1.0


def init_param(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "lru_lambda":
        # RG-LRU: Λ init so a = sigmoid(Λ)^(8c) spreads in [0.9, 0.999]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(u ** (1 / 8.0) / (1 - u ** (1 / 8.0)))
        return lam.astype(dtype)
    fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[0], 1)
    std = d.scale / np.sqrt(fan_in)
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_tree(key: jax.Array, defs, dtype) -> dict:
    """Initialize a (nested) dict of ParamDefs into arrays."""
    flat, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(flat))
    arrs = [init_param(k, d, dtype) for k, d in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def shape_tree(defs, dtype) -> dict:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def axes_tree(defs) -> dict:
    """Logical-axes pytree matching the params structure."""
    return jax.tree_util.tree_map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------


# Activation-sharding mesh: set by the step builders (train_step.py /
# dryrun) before tracing; None (tests, single device) makes ashard a no-op.
_ACTIVATION_MESH = None
# logical "batch"/"model" may be remapped per arch (hillclimb lever), e.g.
# {"batch": ("data", "model")} for small-head archs.
_ACTIVATION_RULES: dict = {}


def set_activation_mesh(mesh, rules: dict | None = None) -> None:
    global _ACTIVATION_MESH, _ACTIVATION_RULES
    _ACTIVATION_MESH = mesh
    _ACTIVATION_RULES = rules or {}


def ashard(x: jax.Array, *logical) -> jax.Array:
    """Activation sharding constraint from logical axis names.

    Logical names: "batch" (→ fsdp axes), "model" (→ model axis), None.
    Without these constraints GSPMD picks operand-derived shardings that
    replicate the global batch through the whole stack (measured 16×
    activation blowup; EXPERIMENTS.md §Perf iteration 0).
    Dims that don't divide the target axes stay unsharded.
    """
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    axis_names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in axis_names)
    default = {"batch": fsdp, "model": ("model",) if "model" in axis_names else ()}
    parts = []
    used: set = set()
    for dim, name in zip(x.shape, logical):
        cand = _ACTIVATION_RULES.get(name, default.get(name, ()))
        cand = tuple(a for a in cand if a in axis_names and a not in used)
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if cand and dim % size == 0:
            parts.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            parts.append(None)
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def model_divides(n: int) -> bool:
    """True iff the active mesh's model axis evenly shards a dim of size n."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return False
    if "model" in _ACTIVATION_RULES and not _ACTIVATION_RULES["model"]:
        return False  # tp_mode="dp": model axis remapped to data parallelism
    return "model" in mesh.axis_names and n % mesh.shape["model"] == 0


def rp_einsum(spec: str, x: jax.Array, w: jax.Array, reduce_dtype: str = "f32") -> jax.Array:
    """Row-parallel einsum (contracts a model-sharded dim → cross-chip
    partial-sum reduction).  reduce_dtype="bf16" makes the HLO dot emit
    bf16 so GSPMD's all-reduce moves half the bytes (the MXU still
    accumulates f32 internally on TPU)."""
    if reduce_dtype == "bf16" and x.dtype == jnp.bfloat16:
        return jnp.einsum(spec, x, w, preferred_element_type=jnp.bfloat16)
    return jnp.einsum(spec, x, w)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 accumulation but NO f32 materialization of x.

    ``x.astype(f32)`` as the first consumer of the residual stream makes
    XLA store the layer-scan's saved carries in f32 (2× activation memory;
    measured +12.9GB/device — EXPERIMENTS.md §Perf iteration 0), so the
    variance is computed via an f32-accumulating einsum on the bf16 values
    and the normalization stays in the compute dtype.
    """
    if x.dtype == jnp.float32:
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + eps)
        return xn * (1.0 + scale.astype(jnp.float32))
    d = x.shape[-1]
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32) / d
    )
    r = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return (x * r) * (1.0 + scale).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along time. x: (B, S, C), w: (C, K).

    Returns (y, new_state) where state holds the last K-1 inputs for decode.
    """
    k = w.shape[-1]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)  # (B, S+K-1, C)
    y = sum(xp[..., i : i + x.shape[-2], :] * w[:, i] for i in range(k))
    new_state = xp[..., -(k - 1) :, :] if k > 1 else pad
    return y.astype(x.dtype), new_state


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


ACTIVATIONS: dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "geglu": jax.nn.gelu,  # gating handled by the FFN structure
    "swiglu": jax.nn.silu,
}
