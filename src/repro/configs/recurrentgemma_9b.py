"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427].

Assigned: 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
Griffin pattern (rglru, rglru, local-attn) ×12 + 2 tail rglru; window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rnn_width=4096,
    conv1d_width=4,
    activation="geglu",
    glu=True,
    emb_scale=True,
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=3,
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    pattern=("rglru", "rglru", "local"),
    window_size=16,
    rnn_width=64,
    activation="geglu",
    glu=True,
    emb_scale=True,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
