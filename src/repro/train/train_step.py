"""Jitted train / prefill / serve steps with GSPMD sharding.

``make_train_step`` builds the pjit'd (params, opt, step, batch) -> ... step
with in/out shardings from the logical-axis rules; ``make_serve_step`` the
one-token decode; ``make_prefill`` the last-logit prefill forward.

Beyond-paper distributed trick (DESIGN.md §5): ``compressed`` mode makes the
``pod`` mesh axis *manual* (jax.shard_map axis_names={"pod"}) while data/model
stay GSPMD-auto: per-pod gradients are int8-quantized with error feedback and
psum'd over the slow inter-pod links, cutting cross-pod gradient traffic 4×
vs bf16.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import model as m
from repro.models.layers import set_activation_mesh
from repro.train import optimizer as opt


def _rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    return shd.default_rules(mesh, tp=cfg.tp_mode != "dp")


def activation_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    if cfg.tp_mode == "dp":
        return {"batch": shd.fsdp_axes(mesh) + ("model",), "model": ()}
    return {}


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int | None = None) -> dict:
    axes = shd.fsdp_axes(mesh)
    if cfg.tp_mode == "dp":
        axes = axes + ("model",)
    if global_batch is not None:
        # drop trailing axes until the batch divides (e.g. batch 256 in dp
        # mode on 512 chips keeps ("pod","data") and leaves model for GSPMD)
        import math

        while axes and global_batch % math.prod(mesh.shape[a] for a in axes):
            axes = axes[:-1]
    bspec = P(axes) if axes else P()
    specs = {"tokens": bspec, "labels": bspec}
    if cfg.frontend != "none":
        specs["frontend_emb"] = bspec
    return specs


def param_specs(cfg: ModelConfig, mesh: Mesh):
    axes = m.param_logical_axes(cfg)
    shapes = m.abstract_params(cfg)
    return shd.tree_specs(axes, shapes, mesh, _rules(cfg, mesh))


def _loss(params, cfg, batch):
    return m.loss_fn(
        params, cfg, batch["tokens"], batch["labels"], batch.get("frontend_emb")
    )


def make_train_step(cfg: ModelConfig, ocfg: opt.OptConfig, mesh: Mesh, *, compressed: bool = False, global_batch: int | None = None):
    """Returns (step_fn, state_shardings) — step_fn is jit'd with shardings.

    state = (params, opt_state, step); batch = {tokens, labels[, frontend_emb]}.
    """
    set_activation_mesh(mesh, activation_rules(cfg, mesh))
    pspecs = param_specs(cfg, mesh)
    ospecs = opt.opt_state_specs(ocfg, pspecs, m.abstract_params(cfg))
    bspecs = batch_specs(cfg, mesh, global_batch)

    def grads_of(params, batch):
        if compressed and "pod" in mesh.axis_names:
            return _podwise_compressed_grads(params, cfg, batch, mesh)
        return jax.value_and_grad(_loss)(params, cfg, batch)

    # guard: a microbatch smaller than the batch-sharding group silently
    # REPLICATES compute on every device (caught 24x flops on multipod
    # arctic — §Perf); fail loudly instead.
    import math

    bs_axes = (tuple(bspecs["tokens"]) or (None,))[0]
    bs_axes = bs_axes if isinstance(bs_axes, tuple) else (bs_axes,)
    group = math.prod(mesh.shape[a] for a in bs_axes if a)

    def step_fn(params, opt_state, step, batch):
        mb = cfg.microbatches
        if mb > 1:
            per_mb = batch["tokens"].shape[0] // mb
            assert per_mb % group == 0, (
                f"microbatch {per_mb} not divisible by batch-sharding group "
                f"{group} — would replicate compute ({cfg.name})"
            )
            # gradient accumulation: activations scale 1/mb (DESIGN.md §5)
            split = jax.tree_util.tree_map(
                lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]), batch
            )

            gshard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), pspecs
            )

            def body(carry, xs):
                gsum, lsum = carry
                loss, grads = grads_of(params, xs)
                # pin per-microbatch grads to the param sharding: the DP
                # reduction becomes a reduce-scatter into the fsdp shard
                # instead of a full all-reduce (§Perf arctic it.2)
                grads = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, grads, gshard
                )
                gsum = jax.tree_util.tree_map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), sh
                ),
                params, gshard,
            )
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), split)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            loss = lsum / mb
        else:
            loss, grads = grads_of(params, batch)
        new_params, new_opt, gnorm = opt.opt_update(ocfg, grads, opt_state, params, step)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": step + 1}
        return new_params, new_opt, step + 1, metrics

    sharding = lambda tree: shd.tree_shardings(tree, mesh)
    jitted = jax.jit(
        step_fn,
        in_shardings=(sharding(pspecs), sharding(ospecs), NamedSharding(mesh, P()),
                      {k: NamedSharding(mesh, v) for k, v in bspecs.items()}),
        out_shardings=(sharding(pspecs), sharding(ospecs), NamedSharding(mesh, P()),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return jitted, (pspecs, ospecs, bspecs)


def _podwise_compressed_grads(params, cfg: ModelConfig, batch, mesh: Mesh):
    """Per-pod grads (GSPMD-auto inside the pod), int8 EF-compressed psum
    across pods.  Activation constraints are disabled inside the manual-pod
    region (full-mesh NamedShardings clash with the Manual axis type; GSPMD
    infers per-pod shardings instead)."""

    @functools.partial(
        shd.shard_map_compat,
        mesh=mesh,
        in_specs=(P(), {k: P("pod") for k in batch}),
        out_specs=(P(), P()),
        axis_names=frozenset({"pod"}),
    )
    def run(params, batch):
        loss, grads = jax.value_and_grad(_loss)(params, cfg, batch)
        npods = mesh.shape["pod"]  # static on every JAX version

        def allreduce_q(g):
            # int8 quantize with per-tensor scale; EF residual dropped inside
            # jit (stateless demo — the trainer holds EF state across steps).
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            summed = jax.lax.psum(q.astype(jnp.float32) * scale, "pod")
            return (summed / npods).astype(g.dtype)

        grads = jax.tree_util.tree_map(allreduce_q, grads)
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads

    set_activation_mesh(None)
    try:
        return run(params, batch)
    finally:
        set_activation_mesh(mesh, activation_rules(cfg, mesh))


def make_prefill(cfg: ModelConfig, mesh: Mesh):
    """Prefill: full forward, return ONLY the last-position logits (the
    (B, S, V) logits tensor must never materialize at 32k)."""

    set_activation_mesh(mesh, activation_rules(cfg, mesh))

    def prefill(params, batch):
        tokens = batch["tokens"]
        logits, _ = m.forward(params, cfg, tokens, batch.get("frontend_emb"))
        return logits[:, -1, :]

    pspecs = param_specs(cfg, mesh)
    bspecs = batch_specs(cfg, mesh)
    bspecs.pop("labels", None)
    out_spec = shd.div_spec(
        mesh, (1 << 30, cfg.vocab_size), shd.fsdp_axes(mesh), "model"
    )
    jitted = jax.jit(
        prefill,
        in_shardings=(shd.tree_shardings(pspecs, mesh),
                      {k: NamedSharding(mesh, v) for k, v in bspecs.items()}),
        out_shardings=NamedSharding(mesh, out_spec),
    )
    return jitted, (pspecs, bspecs)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh):
    """PartitionSpec tree for the decode cache."""
    shapes = m.abstract_cache(cfg, batch, max_len)

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "index" in names:
            return P()
        kind = "kv" if names and names[-1] in ("k", "v") else "state"
        shape = leaf.shape
        # stacked caches have a leading scan axis (n_rep): never sharded
        if "blocks" in names:
            inner = shd.cache_spec(tuple(shape[1:]), kind, mesh)
            return P(None, *inner)
        return shd.cache_spec(tuple(shape), kind, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def make_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    """One-token decode step; cache donated (updated in place)."""
    set_activation_mesh(mesh, activation_rules(cfg, mesh))
    pspecs = param_specs(cfg, mesh)
    cspecs = cache_specs(cfg, batch, max_len, mesh)
    tok_spec = shd.batch_spec(mesh, batch)

    def serve(params, cache, tokens):
        logits, cache = m.decode_step(params, cfg, tokens, cache)
        return logits, cache

    logits_spec = shd.div_spec(
        mesh, (batch, 1, cfg.vocab_size),
        tuple(tok_spec)[0] if len(tuple(tok_spec)) else None, None, "model",
    )
    jitted = jax.jit(
        serve,
        in_shardings=(
            shd.tree_shardings(pspecs, mesh),
            shd.tree_shardings(cspecs, mesh),
            NamedSharding(mesh, tok_spec),
        ),
        out_shardings=(
            NamedSharding(mesh, logits_spec),
            shd.tree_shardings(cspecs, mesh),
        ),
        donate_argnums=(1,),
    )
    return jitted, (pspecs, cspecs)
