"""Owner-routed sharded sampling (repro.shard, DESIGN.md §12).

Two layers:

- In-process tests of the exchange machinery (queue push/pop, per-
  destination routing with overflow deferral, per-device footprint) — pure
  fixed-shape array programs, no mesh required.
- Subprocess tests on a forced 8-host-device mesh (same harness as
  ``test_multidevice.py``): the bit-identical parity contract of
  ``sharded_random_walk`` vs single-device ``random_walk`` for flat- and
  window-bias programs on both backends, overflow round-trips, the
  ``placement="sharded"`` service target, and the instance-parallel
  key-disjointness fix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import MULTIDEVICE_HEADER as HEADER, run_multidevice_child as run_child
from repro.shard import exchange as ex


# ---------------------------------------------------------------------------
# Exchange machinery (in-process, no mesh)
# ---------------------------------------------------------------------------


class TestExchange:
    def test_queue_push_pop_roundtrip_with_payload(self):
        q = ex.make_queue(8, (0, 0, 2))
        ent = (
            jnp.array([5, -1, 7, 9], jnp.int32),
            jnp.array([0, -1, 1, 2], jnp.int32),
            jnp.array([[10, 11], [0, 0], [12, 13], [14, 15]], jnp.int32),
        )
        valid = jnp.array([True, False, True, True])
        q = ex.queue_push(q, ent, valid)
        assert int(q.count) == 3 and int(q.dropped) == 0
        # valid entries keep batch order, front-packed
        np.testing.assert_array_equal(np.asarray(q.fields[0][:3]), [5, 7, 9])
        np.testing.assert_array_equal(np.asarray(q.fields[2][0]), [10, 11])

        out, taken, q = ex.queue_pop(q, 2)
        assert int(taken) == 2
        np.testing.assert_array_equal(np.asarray(out[0]), [5, 7])
        np.testing.assert_array_equal(np.asarray(out[2]), [[10, 11], [12, 13]])
        # survivor re-compacted to the front
        assert int(q.count) == 1 and int(q.fields[0][0]) == 9
        assert int(q.fields[1][1]) == -1  # vacated slot cleared

    def test_queue_pop_limit_caps_take(self):
        q = ex.make_queue(4, (0, 0))
        q = ex.queue_push(
            q,
            (jnp.arange(4, dtype=jnp.int32), jnp.arange(4, dtype=jnp.int32)),
            jnp.ones(4, bool),
        )
        out, taken, q = ex.queue_pop(q, 4, limit=jnp.int32(1))
        assert int(taken) == 1 and int(q.count) == 3
        np.testing.assert_array_equal(np.asarray(out[0]), [0, -1, -1, -1])

    def test_queue_push_overflow_counted(self):
        q = ex.make_queue(2, (0, 0))
        ent = (jnp.arange(4, dtype=jnp.int32), jnp.arange(4, dtype=jnp.int32))
        q = ex.queue_push(q, ent, jnp.ones(4, bool))
        assert int(q.count) == 2 and int(q.dropped) == 2

    def test_route_by_owner_buckets_and_defers(self):
        # 6 valid entries: dests [0, 1, 1, 1, 0, 1]; slots=2 per destination
        vert = jnp.array([0, 10, 11, 12, 1, 13, -1, -1], jnp.int32)
        inst = jnp.array([0, 1, 2, 3, 4, 5, -1, -1], jnp.int32)
        dest = jnp.array([0, 1, 1, 1, 0, 1, 0, 0], jnp.int32)
        valid = inst >= 0
        send, sent, leftover, left = ex.route_by_owner(
            (vert, inst), dest, valid, num_dest=2, slots=2
        )
        np.testing.assert_array_equal(np.asarray(sent), [2, 2])
        # batch order within destination: older entries win the slots
        np.testing.assert_array_equal(np.asarray(send[0][0]), [0, 1])
        np.testing.assert_array_equal(np.asarray(send[0][1]), [10, 11])
        # the two overflowing dest-1 entries defer, front-packed, in order
        assert int(left) == 2
        np.testing.assert_array_equal(np.asarray(leftover[0][:2]), [12, 13])
        assert int(leftover[1][2]) == -1

    def test_route_then_push_conserves_entries(self):
        """Capacity round-trip: routed + deferred + queued == offered."""
        rng = np.random.default_rng(0)
        n, d, slots = 64, 4, 5
        vert = jnp.asarray(rng.integers(0, 40, n).astype(np.int32))
        inst = jnp.asarray(np.arange(n, dtype=np.int32))
        dest = (vert // 10).astype(jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        send, sent, leftover, left = ex.route_by_owner(
            (vert, inst), dest, valid, num_dest=d, slots=slots
        )
        assert int(sent.sum() + left) == int(valid.sum())
        assert int(sent.max()) <= slots
        # every sent + deferred instance id appears exactly once
        ids = np.concatenate(
            [np.asarray(send[1]).ravel(), np.asarray(leftover[1])]
        )
        ids = ids[ids >= 0]
        expect = np.asarray(inst)[np.asarray(valid)]
        np.testing.assert_array_equal(np.sort(ids), np.sort(expect))


# ---------------------------------------------------------------------------
# Per-device footprint (host-side property of the shard layout)
# ---------------------------------------------------------------------------


def test_per_device_csr_footprint_scales_inverse_with_devices():
    """Each shard ships O(V/D + E_D) arrays — never the O(V) indptr of the
    replicated-psum layout — and per-device edge storage shrinks with D."""
    from repro.graph import powerlaw_graph
    from repro.graph.partition import PartitionMap, partition_by_vertex_range

    g = powerlaw_graph(4096, seed=7, weighted=True)
    e_total = g.num_edges
    prev_pad_e = None
    for ndev in (2, 4, 8):
        pm = PartitionMap.create(g.num_vertices, ndev)
        parts = partition_by_vertex_range(g, ndev)
        align = 512
        pad_e = max((p.edge_lo % align) + p.num_edges for p in parts)
        dev = parts[0].to_local_device_csr(
            pad_vertices=pm.range_size, pad_edges=pad_e, edge_align=align
        )
        # indptr rows ∝ V/D (+2: phantom sink + fence), not V+1
        assert dev.graph.indptr.shape[0] == pm.range_size + 2
        # per-device edge arrays well under the full graph, shrinking with D
        assert pad_e <= 3 * e_total // ndev + align
        if prev_pad_e is not None:
            assert pad_e < prev_pad_e
        prev_pad_e = pad_e


def test_edge_alignment_preserves_global_block_offsets():
    from repro.graph import powerlaw_graph
    from repro.graph.partition import partition_by_vertex_range

    g = powerlaw_graph(1024, seed=3, weighted=True)
    parts = partition_by_vertex_range(g, 4)
    indptr = np.asarray(g.indptr)
    for p in parts:
        dev = p.to_local_device_csr(edge_align=512)
        local = np.asarray(dev.graph.indptr)
        for v in range(p.vertex_lo, min(p.vertex_hi, p.vertex_lo + 50)):
            assert local[v - p.vertex_lo] % 512 == indptr[v] % 512


# ---------------------------------------------------------------------------
# Mesh execution (subprocess, forced 8 host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_walk_bit_identical_reference_backend():
    """Flat AND window programs, 4- and 8-way meshes, reference backend:
    sharded == single-device bit for bit, including with tiny exchange
    buffers (overflow deferred across rounds, never dropped)."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
g = powerlaw_graph(1500, exponent=1.9, seed=5, weighted=True)
md = g.max_degree()
seeds = jax.random.randint(jax.random.PRNGKey(0), (96,), 0, g.num_vertices)
key = jax.random.PRNGKey(11)
out = {}
for D in (4, 8):
    mesh = jax.make_mesh((D,), ("data",))
    for spec, kw in [
        (alg.deepwalk(), {}),
        (alg.weighted_random_walk(), {}),
        (alg.biased_random_walk(), {}),          # neighbor-degree flat bias
        (alg.node2vec(), {}),                    # prev-carried window bias
        (alg.random_walk_with_restart(0.25), {}),  # teleport-home epilogue
        (alg.deepwalk(), dict(exchange_slots=3)),  # forced overflow deferral
        (alg.node2vec(), dict(exchange_slots=4)),
    ]:
        ref = random_walk(g, seeds, key, depth=10, spec=spec,
                          max_degree=md, backend="reference")
        res = sharded_random_walk(mesh, g, seeds, key, depth=10, spec=spec,
                                  max_degree=md, backend="reference", **kw)
        tag = f"{D}/{spec.name}/{'slots' if kw else 'full'}"
        out[tag] = bool(jnp.array_equal(ref.walks, res.walks)) and bool(
            jnp.array_equal(ref.lengths, res.lengths))
print(json.dumps(out))
""")
    assert all(d.values()), {k: v for k, v in d.items() if not v}


@pytest.mark.slow
def test_sharded_walk_bit_identical_pallas_backend():
    """Interpret-mode Pallas under shard_map: same bits as the single-device
    pallas path for a flat and a window program."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
g = powerlaw_graph(300, seed=3, weighted=True)
md = g.max_degree()
seeds = jax.random.randint(jax.random.PRNGKey(0), (24,), 0, g.num_vertices)
key = jax.random.PRNGKey(7)
mesh = jax.make_mesh((4,), ("data",))
out = {}
for spec in (alg.deepwalk(), alg.node2vec()):
    ref = random_walk(g, seeds, key, depth=4, spec=spec,
                      max_degree=md, backend="pallas")
    res = sharded_random_walk(mesh, g, seeds, key, depth=4, spec=spec,
                              max_degree=md, backend="pallas")
    out[spec.name] = bool(jnp.array_equal(ref.walks, res.walks))
print(json.dumps(out))
""", timeout=600)
    assert all(d.values()), d


@pytest.mark.slow
def test_sharded_walk_hub_degrees_hit_every_cohort():
    """Degrees spanning small bucket, medium bucket, and the chunked
    huge-degree tail (> 512) stay bit-identical across the exchange."""
    d = run_child(HEADER + """
from repro.graph import csr_from_edges
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
rng = np.random.default_rng(0)
V = 2000
src = np.concatenate([np.zeros(900, int), np.full(300, 1000), rng.integers(0, V, 4000)])
dst = np.concatenate([rng.integers(1, V, 900), rng.integers(0, V, 300), rng.integers(0, V, 4000)])
w = rng.random(src.shape[0]).astype(np.float32) + 0.1
g = csr_from_edges(V, src, dst, weights=w, symmetrize=True)
md = g.max_degree()
assert md > 512  # the chunked tail must actually engage
seeds = jnp.asarray(np.concatenate([[0, 1000], rng.integers(0, V, 62)]).astype(np.int32))
key = jax.random.PRNGKey(13)
mesh = jax.make_mesh((8,), ("data",))
out = {"maxdeg": int(md)}
for spec in (alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()):
    ref = random_walk(g, seeds, key, depth=8, spec=spec, max_degree=md, backend="reference")
    res = sharded_random_walk(mesh, g, seeds, key, depth=8, spec=spec, max_degree=md, backend="reference")
    out[spec.name] = bool(jnp.array_equal(ref.walks, res.walks))
print(json.dumps(out))
""")
    assert d["maxdeg"] > 512
    assert all(v for k, v in d.items() if k != "maxdeg"), d


@pytest.mark.slow
def test_sharded_service_cohorts():
    """placement="sharded": heterogeneous request cohorts drain through the
    mesh, return exact per-request geometry, walk real edges, and are
    deterministic across identically-constructed services."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.serve import SamplingService
g = powerlaw_graph(1000, seed=0, weighted=True)
mesh = jax.make_mesh((8,), ("data",))

def serve():
    svc = SamplingService(g, mesh=mesh, placement="sharded",
                          backend="reference", key=jax.random.PRNGKey(9))
    rng = np.random.default_rng(1)
    tickets = {}
    for i in range(8):
        spec = [alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()][i % 3]
        n, dep = int(rng.integers(8, 49)), int(rng.choice([4, 6, 10]))
        rid = svc.submit(rng.integers(0, 1000, n), depth=dep, spec=spec)
        tickets[rid] = (n, dep)
    return svc, tickets, svc.drain()

svc, tickets, res = serve()
_, _, res2 = serve()
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
geom_ok, edges_ok, det_ok = True, True, True
for rid, (n, dep) in tickets.items():
    r = res[rid]
    geom_ok &= r.walks.shape == (n, dep + 1) and bool((r.lengths >= 1).all())
    det_ok &= bool(np.array_equal(r.walks, res2[rid].walks))
    for row in r.walks:
        for a, b in zip(row[:-1], row[1:]):
            if a < 0 or b < 0: break
            edges_ok &= b in ind[ip[a]:ip[a+1]]
print(json.dumps({"geom": geom_ok, "edges": bool(edges_ok), "det": det_ok,
                  "launches": svc.stats.sharded_launches}))
""")
    assert d["geom"] and d["edges"] and d["det"] and d["launches"] >= 1


@pytest.mark.slow
def test_instance_parallel_streams_disjoint_across_mesh_sizes():
    """Folding the axis size means device d of a 2-way and a 4-way mesh draw
    different streams — before the fix, the first instance group's walks
    were identical across mesh widths (same ``fold_in(key, d)``)."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk
g = powerlaw_graph(512, seed=1, weighted=True)
seeds = jax.random.randint(jax.random.PRNGKey(0), (64,), 0, 512)
runs = {}
for D in (2, 4):
    mesh = jax.make_mesh((D,), ("data",))
    res = instance_parallel_walk(mesh, g, seeds, jax.random.PRNGKey(1), depth=16,
                                 spec=alg.deepwalk(), max_degree=g.max_degree())
    runs[D] = np.asarray(res.walks)
# device 0 of the 4-way mesh owns instances [0:16); under the old keying it
# replayed device 0 of the 2-way mesh verbatim
head_differs = not np.array_equal(runs[2][:16], runs[4][:16])
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
bad = 0
for row in runs[4]:
    for a, b in zip(row[:-1], row[1:]):
        if a < 0 or b < 0: break
        if b not in ind[ip[a]:ip[a+1]]: bad += 1
print(json.dumps({"head_differs": bool(head_differs), "bad": bad}))
""")
    assert d["head_differs"] and d["bad"] == 0
