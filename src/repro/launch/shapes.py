"""Assigned input-shape cells and ``input_specs`` (ShapeDtypeStruct only).

Cell policy (DESIGN.md §4):
  - train_4k    → train_step      (seq 4096,   global_batch 256)
  - prefill_32k → prefill         (seq 32768,  global_batch 32)
  - decode_32k  → serve_step      (KV cache 32768, global_batch 128)
  - long_500k   → serve_step      (KV cache 524288, global_batch 1);
                  sub-quadratic archs only (ssm/hybrid/mostly-local).
For ``[audio]``/``[vlm]`` archs the frontend is a stub: ``frontend_emb``
ShapeDtypeStructs stand in for precomputed frame/patch embeddings and the
token span shrinks so total sequence length matches the assigned seq_len.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# archs allowed to run long_500k (sub-quadratic decode memory/compute)
LONG_OK = {"xlstm-350m", "recurrentgemma-9b", "gemma3-1b"}


def cell_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, "pure full-attention arch: 512k KV decode skipped (DESIGN.md §4)"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    f = cfg.frontend_tokens if cfg.frontend != "none" else 0
    tok = jax.ShapeDtypeStruct((b, s - f), jnp.int32)
    specs: dict = {}
    if sh["kind"] == "train":
        specs["tokens"] = tok
        specs["labels"] = jax.ShapeDtypeStruct((b, s - f), jnp.int32)
        if f:
            specs["frontend_emb"] = jax.ShapeDtypeStruct((b, f, cfg.d_model), jnp.dtype(cfg.dtype))
    elif sh["kind"] == "prefill":
        specs["tokens"] = tok
        if f:
            specs["frontend_emb"] = jax.ShapeDtypeStruct((b, f, cfg.d_model), jnp.dtype(cfg.dtype))
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return specs
