"""C-SAW core: bias-centric sampling and random walk, TPU-native JAX.

The paper's primary contribution lives here: the bias API (api.py), ITS
selection with bipartite region search (select.py), the batched
multi-instance engines (engine.py), the algorithm zoo (algorithms.py), the
out-of-memory partition scheduler (oom.py), and multi-device sampling
(distributed.py).
"""
from repro.core.api import (
    EdgeCtx,
    SamplingSpec,
    VertexCtx,
    degree_edge_bias,
    degree_vertex_bias,
    uniform_edge_bias,
    uniform_vertex_bias,
    weight_edge_bias,
)
from repro.core.select import (
    SelectResult,
    build_ctps,
    its_search,
    select_with_replacement,
    select_without_replacement,
    walk_transition_chunked,
)
from repro.core.engine import (
    SampleResult,
    WalkResult,
    random_walk,
    random_walk_segments,
    traversal_sample,
)
from repro.core import algorithms
from repro.core import backend
from repro.core import transition
from repro.core.backend import resolve_backend
from repro.core.transition import (
    FlatBias,
    IdentityEpilogue,
    MHAcceptEpilogue,
    OpaqueBias,
    OpaqueEpilogue,
    TeleportEpilogue,
    TransitionProgram,
    WindowBias,
)

__all__ = [
    "EdgeCtx",
    "SamplingSpec",
    "VertexCtx",
    "degree_edge_bias",
    "degree_vertex_bias",
    "uniform_edge_bias",
    "uniform_vertex_bias",
    "weight_edge_bias",
    "SelectResult",
    "build_ctps",
    "its_search",
    "select_with_replacement",
    "select_without_replacement",
    "walk_transition_chunked",
    "SampleResult",
    "WalkResult",
    "random_walk",
    "random_walk_segments",
    "traversal_sample",
    "algorithms",
    "backend",
    "resolve_backend",
    "transition",
    "TransitionProgram",
    "FlatBias",
    "WindowBias",
    "OpaqueBias",
    "IdentityEpilogue",
    "MHAcceptEpilogue",
    "TeleportEpilogue",
    "OpaqueEpilogue",
]
