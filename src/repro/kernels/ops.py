"""Public jit'd wrappers around the Pallas kernels.

On this CPU container kernels execute with ``interpret=True`` (the kernel
body runs in Python, validating logic and tiling); on TPU the same calls
compile through Mosaic.  Wrappers own RNG (counted threefry outside the
kernel) and shape plumbing (padding, bucketing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph
from repro.kernels.its_select import its_select_pallas
from repro.kernels.walk_step import pad_csr_for_kernel, walk_step_pallas


@functools.partial(jax.jit, static_argnames=("k", "iters", "blk_i"))
def its_select(
    key: jax.Array,
    biases: jax.Array,
    k: int,
    *,
    iters: int = 8,
    blk_i: int = 8,
) -> jax.Array:
    """Without-replacement ITS+BRS selection of ``k`` of P candidates.

    biases: (I, P); returns (I, K) int32 indices, -1 where unfilled.
    """
    rands = jax.random.uniform(key, (biases.shape[0], iters, k), dtype=jnp.float32)
    return its_select_pallas(biases, rands, blk_i=blk_i)


@functools.partial(jax.jit, static_argnames=("max_seg",))
def walk_step(
    key: jax.Array,
    graph: CSRGraph,
    cur: jax.Array,
    *,
    max_seg: int = 512,
) -> jax.Array:
    """One weighted random-walk step for all walkers via the fused kernel.

    Requires max degree <= max_seg (checked by caller / engine bucketing).
    cur: (W,) int32 (-1 = finished walker). Returns next (W,) int32.
    """
    safe = jnp.maximum(cur, 0)
    starts = graph.indptr[safe]
    degs = jnp.where(cur >= 0, graph.indptr[safe + 1] - starts, 0)
    indices, weights = pad_csr_for_kernel(graph.indices, graph.weights, max_seg)
    rand = jax.random.uniform(key, cur.shape, dtype=jnp.float32)
    return walk_step_pallas(starts, degs, indices, weights, rand, max_seg=max_seg)
