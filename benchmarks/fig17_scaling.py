"""Paper Fig. 17: multi-device scaling — instance-parallel AND graph-sharded.

Runs subprocesses with ``--xla_force_host_platform_device_count=N`` so the
parent process keeps its single-device view (per the dry-run isolation
rule).  Wall-clock on shared host cores is not a throughput claim — the
host devices time-slice the same physical cores — so the reported figures
are the *work and memory distribution*: instances per device for the
zero-comm instance-parallel mode, and per-device CSR bytes (∝ 1/D) plus
drain wall time for the owner-routed sharded mode (``repro.shard``,
DESIGN.md §12).  The sharded sweep is written to ``BENCH_shard.json`` so
the mesh-scaling trajectory is tracked across PRs: per-device graph bytes
must fall with D while the drain keeps walking the full pl50k edge set.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import jax

from benchmarks.common import row

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_shard.json"

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk

n = %d
g = powerlaw_graph(20000, exponent=2.1, seed=7, weighted=True)
mesh = jax.make_mesh((n,), ("data",))
key = jax.random.PRNGKey(0)
seeds = jax.random.randint(key, (4096,), 0, g.num_vertices)
md = min(g.max_degree(), 512)
run = lambda: instance_parallel_walk(mesh, g, seeds, key, depth=32,
                                     spec=alg.biased_random_walk(), max_degree=md)
jax.block_until_ready(run().walks)
t0 = time.perf_counter()
res = run()
jax.block_until_ready(res.walks)
secs = time.perf_counter() - t0
print(json.dumps({"devices": n, "secs": secs, "edges": int(res.sampled_edges)}))
"""

_CHILD_SHARDED = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.graph.partition import PartitionMap, partition_by_vertex_range
from repro.shard import sharded_random_walk

n = %d
g = powerlaw_graph(%d, exponent=2.1, seed=7, weighted=True)  # 50000 = BENCH_GRAPHS["pl50k"]
mesh = jax.make_mesh((n,), ("data",))
key = jax.random.PRNGKey(0)
seeds = jax.random.randint(key, (2048,), 0, g.num_vertices)
md = g.max_degree()
# what one device holds: compact local-id CSR + aligned global-id edge
# array — same layout arithmetic sharded_random_walk stages
from repro.core import backend as bk
seg_big = max(bk.walk_bucket_plan(md)[0])
pm = PartitionMap.create(g.num_vertices, n)
parts = partition_by_vertex_range(g, n)
pad_e = max((p.edge_lo %% seg_big) + p.num_edges for p in parts)
# indptr + 4 edge arrays: local ids, global ids, weights, and the sliced
# flat bias (the benchmarked spec is flat-bias; window mode ships 3)
bytes_per_device = 4 * ((pm.range_size + 2) + 4 * pad_e)
run = lambda: sharded_random_walk(mesh, g, seeds, key, depth=32,
                                  spec=alg.biased_random_walk(), max_degree=md)
jax.block_until_ready(run().walks)  # compile + first drain
t0 = time.perf_counter()
res = run()
jax.block_until_ready(res.walks)
secs = time.perf_counter() - t0
print(json.dumps({"devices": n, "secs": secs, "edges": int(res.sampled_edges),
                  "bytes_per_device": int(bytes_per_device),
                  "local_edges_max": int(pad_e), "total_edges": int(g.num_edges)}))
"""


def _child(code: str, timeout: int = 1800) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run() -> list[str]:
    rows = []
    for n in (1, 2, 4):
        d = _child(_CHILD % (n, n), timeout=900)
        rows.append(row(
            f"fig17/devices={n}", d["secs"] * 1e6,
            f"SEPS={d['edges']/d['secs']:.3e};inst_per_dev={4096//n}",
        ))

    results = []
    for n in (1, 2, 4, 8):
        d = _child(_CHILD_SHARDED % (max(n, 1), n, 50000))
        rows.append(row(
            f"fig17/sharded_devices={n}", d["secs"] * 1e6,
            f"SEPS={d['edges']/d['secs']:.3e};"
            f"MB_per_dev={d['bytes_per_device']/1e6:.1f};"
            f"local_edges={d['local_edges_max']}/{d['total_edges']}",
        ))
        results.append({
            "devices": n,
            "seconds": d["secs"],
            "sampled_edges_per_s": d["edges"] / d["secs"],
            "bytes_per_device": d["bytes_per_device"],
            "local_edges_max": d["local_edges_max"],
            "total_edges": d["total_edges"],
        })

    # the distinguishing experiment for "step cost ∝ shard size": hold E/D
    # roughly constant while the FULL graph grows ~10x.  Forced host devices
    # execute the D shards serially on the same cores, so seconds/D is the
    # per-shard drain cost — it must stay flat while total edges explode
    # (the replicated-psum design's per-step cost grows with full V instead).
    const_shard = []
    for v, n in ((12500, 1), (25000, 2), (50000, 4), (100000, 8)):
        d = _child(_CHILD_SHARDED % (max(n, 1), n, v))
        per_shard = d["secs"] / n
        rows.append(row(
            f"fig17/const_shard V={v} D={n}", d["secs"] * 1e6,
            f"secs_per_shard={per_shard:.3f};edges_per_dev={d['total_edges']//n}",
        ))
        const_shard.append({
            "vertices": v,
            "devices": n,
            "total_edges": d["total_edges"],
            "edges_per_device": d["total_edges"] // n,
            "seconds": d["secs"],
            "seconds_per_shard": per_shard,
        })
    payload = {
        "bench": "owner-routed sharded walk scaling (pl50k, 2048 walkers, depth 32)",
        "device": jax.default_backend(),
        "note": "forced host devices share physical cores (wall time is not a "
                "multi-chip throughput claim): bytes_per_device is the scaling "
                "metric of the device sweep, and seconds_per_shard of the "
                "constant-shard sweep must stay flat from D=2 up while "
                "total_edges grows ~10x (D=1 pays no exchange collective, so "
                "it sits lower) — scan-step cost tracks shard size, not "
                "full-graph size",
        "results": results,
        "constant_shard_scaling": const_shard,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


def main() -> None:
    for r in run():
        print(r)
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
