"""Decoder LM: param construction, train forward, prefill, decode step.

Layer stacks scan over *pattern superblocks* (one repetition of
``cfg.pattern``) so heterogeneous stacks stay scannable: params for the
``n_rep`` whole repetitions are stacked on a leading axis and consumed by
``lax.scan`` (small HLO, fast 512-device compiles); remainder layers are
unrolled ("tail").  Remat wraps each superblock in train mode.

Modality frontends (musicgen audio frames / internvl2 patch embeddings) are
STUBS by assignment: ``input_specs`` supplies precomputed embeddings that are
prepended to the token embedding sequence.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import (
    ParamDef,
    ashard,
    axes_tree,
    init_tree,
    rms_norm,
    shape_tree,
    softcap,
)


def _superblock_defs(cfg: ModelConfig) -> list:
    return [blocks.block_defs(cfg, kind) for kind in cfg.pattern]


@jax.custom_vjp
def _barrier(x):
    """Differentiable ``optimization_barrier``.

    The primitive has no autodiff rule on the pinned JAX version, so
    differentiating the scanned superblock dies inside ``lax.scan``.  The
    custom VJP barriers both directions — which is also the semantics we
    want: the backward pass is exactly where XLA would otherwise hoist the
    saved-carry dtype converts this barrier exists to prevent."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


# logical param axes that map to the model (TP) mesh axis; everything else
# (fsdp-sharded dims) is gathered at use time.
_MODEL_AXES = {"heads", "kv_heads", "mlp", "experts", "rnn", "vocab"}


def _gather_fsdp(params, defs, tp: bool = True):
    """FSDP weight gathering: re-constrain used weights so only their
    model/TP axes stay sharded.  Without this GSPMD contracts matmuls over
    the fsdp-sharded dim and all-reduces full activations every layer
    (measured 80GB/step/device on internlm2 — EXPERIMENTS.md §Perf it. 0).
    Runs inside the remat'd superblock, so backward re-gathers (standard
    FSDP+remat schedule).  tp=False gathers everything (tp_mode="dp")."""
    from repro.models.layers import _ACTIVATION_MESH
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _ACTIVATION_MESH
    if mesh is None:
        return params

    def one(p, d):
        parts = [
            "model"
            if (tp and a in _MODEL_AXES and dim % mesh.shape["model"] == 0)
            else None
            for a, dim in zip(d.axes, d.shape)
        ]
        # a mesh axis may appear at most once
        seen = False
        for i, x in enumerate(parts):
            if x == "model":
                if seen:
                    parts[i] = None
                seen = True
        return jax.lax.with_sharding_constraint(p, NamedSharding(mesh, P(*parts)))

    return jax.tree_util.tree_map(
        one, params, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def model_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    defs: dict = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": ParamDef((d,), (None,), init="zeros"),
    }
    if cfg.n_rep:
        # stacked along a leading n_rep axis for lax.scan
        defs["blocks"] = jax.tree_util.tree_map(
            lambda p: ParamDef((cfg.n_rep,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
            _superblock_defs(cfg),
            is_leaf=lambda x: isinstance(x, ParamDef),
        )
    tail_kinds = cfg.layer_kinds()[cfg.n_rep * len(cfg.pattern) :]
    if tail_kinds:
        defs["tail"] = [blocks.block_defs(cfg, k) for k in tail_kinds]
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))
    if cfg.frontend != "none":
        defs["frontend_proj"] = ParamDef((d, d), ("embed", None))
    return defs


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    return init_tree(key, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return shape_tree(model_defs(cfg), jnp.dtype(cfg.param_dtype))


def param_logical_axes(cfg: ModelConfig) -> dict:
    return axes_tree(model_defs(cfg))


def _embed(params, cfg: ModelConfig, tokens: jax.Array, frontend_emb) -> jax.Array:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.emb_scale:
        x = x * jnp.sqrt(jnp.array(cfg.d_model, jnp.float32)).astype(x.dtype)
    if cfg.frontend != "none" and frontend_emb is not None:
        fe = jnp.einsum("bsd,de->bse", frontend_emb.astype(x.dtype), params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    return ashard(x, "batch", None, None)


def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S)
    frontend_emb: Optional[jax.Array] = None,  # (B, F, D) for audio/vlm stubs
) -> Tuple[jax.Array, jax.Array]:
    """Decoder trunk. Returns (final-normed hidden (B, S_total, D), aux)."""
    x = _embed(params, cfg, tokens, frontend_emb)
    positions = jnp.arange(x.shape[1])[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    sb_defs = _superblock_defs(cfg)

    def superblock(x, rep_params):
        # barrier: stops XLA hoisting per-step dtype converts of the saved
        # carry OUT of the backward loop (materializes the whole (n_rep, B,
        # S, D) history in f32 otherwise — measured 12.9GB/device on
        # internlm2; EXPERIMENTS.md §Perf iteration 0).
        x = _barrier(x)
        rep_params = _gather_fsdp(rep_params, sb_defs, tp=cfg.tp_mode != "dp")
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.pattern):
            x, a = blocks.block_train(rep_params[i], cfg, kind, x, positions)
            x = ashard(x, "batch", None, None)
            aux = aux + a
        return x, aux

    if cfg.remat == "full":
        superblock = jax.checkpoint(superblock, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        # save matmul outputs: backward skips recomputing the flop/traffic
        # heavy dots (incl. their TP all-reduces) at the cost of storing
        # per-layer dot outputs (§Perf gemma-7b it.3)
        superblock = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    if cfg.n_rep and "blocks" in params:
        def scan_body(x, rep_params):
            return superblock(x, rep_params)

        x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
        aux_total = aux_total + jnp.sum(auxs)
    tail_kinds = cfg.layer_kinds()[cfg.n_rep * len(cfg.pattern) :]
    for i, kind in enumerate(tail_kinds):
        tparams = _gather_fsdp(
            params["tail"][i], blocks.block_defs(cfg, kind), tp=cfg.tp_mode != "dp"
        )
        x, a = blocks.block_train(tparams, cfg, kind, x, positions)
        aux_total = aux_total + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    frontend_emb: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full forward with logits (prefill/decode-scale shapes only —
    training uses loss_fn's chunked CE so (B,S,V) never materializes)."""
    x, aux_total = forward_hidden(params, cfg, tokens, frontend_emb)
    head = params["head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = ashard(softcap(logits, cfg.logit_softcap), "batch", None, "model")
    return logits, aux_total


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode cache pytree, stacked to mirror the params layout."""
    dtype = jnp.dtype(cfg.dtype)
    cache: dict = {}
    if cfg.n_rep:
        per_rep = [
            blocks.block_cache_init(cfg, kind, batch, max_len, dtype) for kind in cfg.pattern
        ]
        # stack n_rep copies along a leading scan axis
        cache["blocks"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_rep,) + x.shape), per_rep
        )
    tail_kinds = cfg.layer_kinds()[cfg.n_rep * len(cfg.pattern) :]
    if tail_kinds:
        cache["tail"] = [
            blocks.block_cache_init(cfg, k, batch, max_len, dtype) for k in tail_kinds
        ]
    cache["index"] = jnp.zeros((), jnp.int32)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_step(
    params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict
) -> Tuple[jax.Array, dict]:
    """One-token decode. tokens: (B, 1). Returns (logits (B, 1, V), cache)."""
    x = _embed(params, cfg, tokens, None)
    index = cache["index"]

    if cfg.n_rep and "blocks" in params:
        def scan_body(x, xs):
            rep_params, rep_cache = xs
            new_cache = []
            for i, kind in enumerate(cfg.pattern):
                x, c = blocks.block_decode(rep_params[i], cfg, kind, x, rep_cache[i], index)
                new_cache.append(c)
            return x, new_cache

        x, new_blocks = jax.lax.scan(scan_body, x, (params["blocks"], cache["blocks"]))
        cache = dict(cache, blocks=new_blocks)
    tail_kinds = cfg.layer_kinds()[cfg.n_rep * len(cfg.pattern) :]
    if tail_kinds:
        new_tail = []
        for i, kind in enumerate(tail_kinds):
            x, c = blocks.block_decode(params["tail"][i], cfg, kind, x, cache["tail"][i], index)
            new_tail.append(c)
        cache = dict(cache, tail=new_tail)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = softcap(logits, cfg.logit_softcap)
    cache = dict(cache, index=index + 1)
    return logits, cache


def loss_fn(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S) inputs
    labels: jax.Array,  # (B, S) targets (-100 = masked)
    frontend_emb: Optional[jax.Array] = None,
    aux_weight: float = 0.01,
    loss_chunk: int | None = None,
) -> jax.Array:
    """Cross entropy with *chunked* logits: the (B, S, V) tensor never
    materializes (at vocab 262k × 1M tokens it would be ~4GB f32 per device
    plus its cotangent — EXPERIMENTS.md §Perf iteration 0).  Each sequence
    chunk computes logits → logsumexp → NLL under remat."""
    x, aux = forward_hidden(params, cfg, tokens, frontend_emb)
    if cfg.frontend != "none" and frontend_emb is not None:
        x = x[:, frontend_emb.shape[1] :]
    head = params["head"] if not cfg.tie_embeddings else params["embed"].T
    head = head.astype(x.dtype)
    # gather the head's fsdp (embed) dim; keep vocab sharded on model
    head = ashard(head, None, "model")
    b, s, d = x.shape
    from repro.models.attention import pick_chunk

    c = pick_chunk(s, loss_chunk or cfg.loss_chunk)
    nc = s // c
    xs = x.reshape(b, nc, c, d).swapaxes(0, 1)  # (nc, B, c, D)
    ls = labels.reshape(b, nc, c).swapaxes(0, 1)

    def chunk_nll(carry, xs_):
        xc, lc = xs_
        logits = jnp.einsum("bcd,dv->bcv", xc, head, preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.logit_softcap)
        mask = lc >= 0
        safe = jnp.maximum(lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll, cnt = carry
        return (nll + jnp.sum((logz - gold) * mask), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(chunk_nll, policy=jax.checkpoint_policies.nothing_saveable)
    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xs, ls))
    return nll / jnp.maximum(cnt, 1) + aux_weight * aux
