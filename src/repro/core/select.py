"""Bias-based vertex selection (paper §II-B, §IV).

This module is the heart of C-SAW: turning per-candidate *biases* into
selections via Inverse Transform Sampling (ITS) over the Cumulative
Transition Probability Space (CTPS), with *bipartite region search* (BRS,
paper §IV-B, Theorem 2) to mitigate selection collisions when sampling
without replacement.

All functions are batched over arbitrary leading instance dimensions and are
jit/vmap/shard_map friendly (fixed shapes, masked semantics, counted RNG).

Selection modes (``SelectMethod``):
  - ``its_brs``   — paper-faithful: ITS + bipartite region search retry.
  - ``repeated``  — naive baseline (paper Fig. 6(a)): fresh re-draw on collision.
  - ``updated``   — recompute the CTPS excluding selected (paper Fig. 6(b)).
  - ``gumbel``    — beyond-paper TPU-native: Gumbel top-k (Plackett-Luce);
                    distributionally identical to sequential without-replacement
                    ITS, collision-free by construction.
"""
from __future__ import annotations

import functools
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

SelectMethod = Literal["its_brs", "repeated", "updated", "gumbel"]

_EPS = 1e-12

#: Rejection-sampling retry budget per walk step (counted-RNG rounds).  Under
#: the cost model's near-uniform guard (acceptance rate >= 0.75) the chance of
#: exhausting all rounds is <= 0.25**8 ~ 1.5e-5; exhaustion falls back to the
#: last candidate (still a real neighbor) rather than killing the walker.
REJECT_ITERS = 8


def build_ctps(biases: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Inclusive normalized prefix sum of biases: the CTPS (paper Eq. 1).

    Region of candidate ``j`` is ``[ctps[j-1], ctps[j])`` with ``ctps[-1]=0``.
    Masked/zero-bias candidates get zero-width regions (unselectable).
    """
    if mask is not None:
        biases = jnp.where(mask, biases, 0.0)
    biases = jnp.maximum(biases.astype(jnp.float32), 0.0)
    sums = jnp.cumsum(biases, axis=-1)
    total = sums[..., -1:]
    return sums / jnp.maximum(total, _EPS)


def its_search(ctps: jax.Array, r: jax.Array) -> jax.Array:
    """Locate the CTPS region containing ``r`` (vectorized 'binary search').

    On TPU a lane-parallel compare-count beats a serial binary search for
    pool sizes up to a few thousand; this is also exactly what the Pallas
    kernel does.  ``r`` has shape ``ctps.shape[:-1] + (k,)``.
    """
    # count of regions with upper boundary <= r  ==  index of containing region
    idx = jnp.sum(ctps[..., None, :] <= r[..., :, None], axis=-1)
    return jnp.clip(idx, 0, ctps.shape[-1] - 1).astype(jnp.int32)


def select_with_replacement(
    key: jax.Array, biases: jax.Array, mask: jax.Array | None, k: int
) -> jax.Array:
    """ITS selection *with* replacement (random-walk case, paper Table I)."""
    ctps = build_ctps(biases, mask)
    r = jax.random.uniform(key, ctps.shape[:-1] + (k,), dtype=jnp.float32)
    return its_search(ctps, r)


class SelectResult(NamedTuple):
    indices: jax.Array  # (..., k) int32, -1 where selection failed/invalid
    valid: jax.Array  # (..., k) bool
    iters: jax.Array  # (...,) int32 — retry-loop trip count (paper Fig. 11)
    searches: jax.Array  # (...,) int32 — total CTPS searches (paper Fig. 12)
    #: True when the dispatcher silently served a pallas request from the
    #: reference path (method without a kernel) — observability for the
    #: adaptive method auto-pick (DESIGN.md §13).
    fell_back: bool = False


def _dedup_priority(cand: jax.Array, active: jax.Array) -> jax.Array:
    """Within-round conflict resolution: lowest-lane duplicate wins.

    TPU adaptation of the paper's atomic bitmap (DESIGN.md §2): a K×K
    equality matrix + lower-triangular priority replaces atomicCAS.
    Returns a boolean 'winner' mask over the k draws.
    """
    k = cand.shape[-1]
    eq = cand[..., :, None] == cand[..., None, :]  # (..., k, k)
    both = active[..., :, None] & active[..., None, :]
    lower = jnp.tril(jnp.ones((k, k), dtype=bool), k=-1)
    beaten = jnp.any(eq & both & lower, axis=-1)  # an earlier lane took it
    return active & ~beaten


def retry_randoms(key: jax.Array, batch_shape: tuple, iters: int, k: int) -> jax.Array:
    """Pre-generated retry budget: ``(..., iters, k)`` uniforms.

    Round ``t`` holds exactly the bits ``_select_its_loop`` draws in round
    ``t`` (``uniform(fold_in(key, t), batch + (k,))``) — this is the counted
    RNG contract that makes the Pallas kernel path in ``core.backend``
    bit-identical to the reference retry loop (DESIGN.md §6).
    """
    if iters < 1:
        raise ValueError(f"retry budget needs at least one round, got iters={iters}")
    rs = [
        jax.random.uniform(jax.random.fold_in(key, t), tuple(batch_shape) + (k,), dtype=jnp.float32)
        for t in range(iters)
    ]
    return jnp.stack(rs, axis=-2)


def select_without_replacement(
    key: jax.Array,
    biases: jax.Array,
    mask: jax.Array | None,
    k: int,
    method: SelectMethod = "its_brs",
    max_iters: int = 32,
) -> SelectResult:
    """Select ``k`` distinct candidates with probability proportional to bias.

    biases: (..., P); mask: (..., P) bool or None; returns indices (..., k).
    If fewer than k candidates are selectable the tail is marked invalid.
    """
    if method == "gumbel":
        return _select_gumbel(key, biases, mask, k)
    if method == "updated":
        return _select_updated(key, biases, mask, k)
    return _select_its_loop(key, biases, mask, k, use_brs=(method == "its_brs"), max_iters=max_iters)


def _select_gumbel(key, biases, mask, k) -> SelectResult:
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    if mask is not None:
        b = jnp.where(mask, b, 0.0)
    logits = jnp.log(jnp.maximum(b, _EPS))
    logits = jnp.where(b > 0, logits, -jnp.inf)
    g = jax.random.gumbel(key, b.shape, dtype=jnp.float32)
    keys_ = jnp.where(jnp.isfinite(logits), logits + g, -jnp.inf)
    _, idx = jax.lax.top_k(keys_, k)
    navail = jnp.sum((b > 0), axis=-1)
    valid = jnp.arange(k) < navail[..., None]
    idx = jnp.where(valid, idx, -1).astype(jnp.int32)
    zeros = jnp.zeros(b.shape[:-1], dtype=jnp.int32)
    return SelectResult(idx, valid, zeros + 1, zeros + k)


def _select_updated(key, biases, mask, k) -> SelectResult:
    """Paper Fig. 6(b): recompute CTPS after every selection (oracle baseline)."""
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    if mask is not None:
        b = jnp.where(mask, b, 0.0)
    batch_shape = b.shape[:-1]

    def body(i, carry):
        b_cur, out, valid = carry
        ctps = build_ctps(b_cur)
        r = jax.random.uniform(jax.random.fold_in(key, i), batch_shape + (1,))
        idx = its_search(ctps, r)[..., 0]
        ok = jnp.take_along_axis(b_cur, idx[..., None], axis=-1)[..., 0] > 0
        out = out.at[..., i].set(jnp.where(ok, idx, -1))
        valid = valid.at[..., i].set(ok)
        b_cur = b_cur * (1.0 - jax.nn.one_hot(idx, b.shape[-1], dtype=b.dtype))
        return b_cur, out, valid

    out = jnp.full(batch_shape + (k,), -1, dtype=jnp.int32)
    valid = jnp.zeros(batch_shape + (k,), dtype=bool)
    _, out, valid = jax.lax.fori_loop(0, k, body, (b, out, valid))
    zeros = jnp.zeros(batch_shape, dtype=jnp.int32)
    return SelectResult(out, valid, zeros + k, zeros + k)


def _select_its_loop(key, biases, mask, k, *, use_brs: bool, max_iters: int) -> SelectResult:
    """ITS without replacement with the paper's retry loop (Fig. 5 lines 9-14).

    Each round, every unfinished draw gets a fresh uniform r'; draws that hit
    an already-selected region either (a) re-draw next round (``repeated``) or
    (b) apply one bipartite-region-search adjustment within the round
    (``its_brs``, paper steps 1-5) and only fall back to a fresh random if the
    adjusted r *also* lands on a selected region ("go to 1").
    """
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    if mask is not None:
        b = jnp.where(mask, b, 0.0)
    batch_shape = b.shape[:-1]
    p = b.shape[-1]
    ctps = build_ctps(b)
    lower = jnp.concatenate([jnp.zeros_like(ctps[..., :1]), ctps[..., :-1]], axis=-1)
    navail = jnp.sum(b > 0, axis=-1)
    want = jnp.minimum(navail, k)  # can't select more than available

    def sel_at(selmask, idx):
        return jnp.take_along_axis(selmask, idx, axis=-1)

    def cond(carry):
        it, done, _, _, _, _ = carry
        return jnp.logical_and(it < max_iters, jnp.any(~done))

    def body(carry):
        it, done, out, selmask, iters, searches = carry
        # NOTE: this per-round draw is the counted-RNG contract shared with
        # retry_randoms()/the Pallas kernel path — change both or neither.
        rkey = jax.random.fold_in(key, it)
        r1 = jax.random.uniform(rkey, batch_shape + (k,), dtype=jnp.float32)
        pending = ~done
        idx1 = its_search(ctps, r1)
        hit1 = sel_at(selmask, idx1)  # collided with previously-selected
        searches = searches + jnp.sum(pending, axis=-1)
        if use_brs:
            # Bipartite region search (paper §IV-B): transform r, reuse CTPS.
            l = jnp.take_along_axis(lower, idx1, axis=-1)
            h = jnp.take_along_axis(ctps, idx1, axis=-1)
            delta = h - l
            r2 = r1 * (1.0 - delta)
            r2 = jnp.where(r2 < l, r2, r2 + delta)
            r2 = jnp.clip(r2, 0.0, 1.0 - _EPS)
            idx2 = its_search(ctps, r2)
            hit2 = sel_at(selmask, idx2)
            searches = searches + jnp.sum(pending & hit1, axis=-1)
            cand = jnp.where(hit1, idx2, idx1)
            ok = pending & ~jnp.where(hit1, hit2, hit1)
        else:
            cand = idx1
            ok = pending & ~hit1
        # candidate must carry probability mass
        ok = ok & (jnp.take_along_axis(b, cand, axis=-1) > 0)
        # within-round dedup: lowest lane wins (DESIGN.md conflict matrix)
        win = _dedup_priority(cand, ok)
        # rank of each newly finished draw -> stable output order
        out = jnp.where(win, cand, out)
        onehot = jax.nn.one_hot(jnp.where(win, cand, 0), p, dtype=bool) & win[..., None]
        selmask = selmask | jnp.any(onehot, axis=-2)
        done_new = done | win
        # stop instances that already have `want` selections
        got = jnp.sum(done_new, axis=-1)
        exhausted = got >= want
        done_new = done_new | (exhausted[..., None] & (jnp.arange(k) >= want[..., None]))
        iters = iters + jnp.any(~done, axis=-1).astype(jnp.int32)
        return it + 1, done_new, out, selmask, iters, searches

    init = (
        jnp.zeros((), jnp.int32),
        jnp.arange(k) >= want[..., None],  # draws beyond availability are done/invalid
        jnp.full(batch_shape + (k,), -1, jnp.int32),
        jnp.zeros(batch_shape + (p,), bool),
        jnp.zeros(batch_shape, jnp.int32),
        jnp.zeros(batch_shape, jnp.int32),
    )
    _, done, out, selmask, iters, searches = jax.lax.while_loop(cond, body, init)
    valid = out >= 0
    return SelectResult(out, valid, iters, searches)


# ---------------------------------------------------------------------------
# Chunked ITS for unbounded-degree rows (no padding): two-pass scan.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def walk_transition_chunked(
    key: jax.Array,
    indptr: jax.Array,
    weights: jax.Array,
    cur: jax.Array,
    chunk: int = 512,
    rand: jax.Array | None = None,
) -> jax.Array:
    """One weighted ITS draw per walker over arbitrarily large neighbor rows.

    Two-pass chunked scan (DESIGN.md §2): pass 1 accumulates the row total,
    pass 2 locates the chunk+offset where the cumulative bias crosses
    ``r * total``.  Returns the *edge offset* within each row (int32), or -1
    for dead ends.  O(max_deg/chunk) steps, fixed memory.  ``rand`` overrides
    the per-walker uniforms (the mesh-sharded drain supplies instance-indexed
    draws so picks match the single-device stream, DESIGN.md §12).
    """
    start = indptr[cur]
    deg = indptr[cur + 1] - start
    nchunks = (jnp.max(deg) + chunk - 1) // chunk  # dynamic upper bound is fine under scan-with-cond
    nchunks = jnp.maximum(nchunks, 1)

    def chunk_sum(c, carry):
        tot = carry
        offs = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        m = offs < deg[..., None]
        w = jnp.where(m, weights[jnp.where(m, start[..., None] + offs, 0)], 0.0)
        return tot + jnp.sum(w, axis=-1)

    max_iters = (weights.shape[0] + chunk - 1) // chunk

    def p1_body(c, tot):
        return jax.lax.cond(c < nchunks, lambda t: chunk_sum(c, t), lambda t: t, tot)

    total = jax.lax.fori_loop(0, max_iters, p1_body, jnp.zeros(cur.shape, jnp.float32))
    r = jax.random.uniform(key, cur.shape, dtype=jnp.float32) if rand is None else rand
    target = r * total

    def p2_body(c, carry):
        cum, found = carry

        def step(args):
            cum, found = args
            offs = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
            m = offs < deg[..., None]
            w = jnp.where(m, weights[jnp.where(m, start[..., None] + offs, 0)], 0.0)
            cw = jnp.cumsum(w, axis=-1) + cum[..., None]
            hit = (cw > target[..., None]) & m & (found[..., None] < 0)
            any_hit = jnp.any(hit, axis=-1)
            first = jnp.argmax(hit, axis=-1) + c * chunk
            found = jnp.where((found < 0) & any_hit, first, found)
            return cw[..., -1], found

        return jax.lax.cond(c < nchunks, step, lambda a: a, (cum, found))

    cum0 = jnp.zeros(cur.shape, jnp.float32)
    found0 = jnp.full(cur.shape, -1, jnp.int32)
    _, found = jax.lax.fori_loop(0, max_iters, p2_body, (cum0, found0))
    # numerical edge: r*total == total -> take last valid edge
    found = jnp.where((found < 0) & (deg > 0) & (total > 0), deg - 1, found)
    return jnp.where((deg > 0) & (total > 0), found, -1)


def walk_transition_chunked_window(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    cur: jax.Array,
    bias_of,
    chunk: int = 512,
    rand: jax.Array | None = None,
) -> jax.Array:
    """Dynamic-bias variant of :func:`walk_transition_chunked`.

    The per-edge bias is not a flat array — it is ``bias_of(u, w, mask,
    eidx)``, the transition program's window-bias hook evaluated on each
    ``(W, chunk)`` edge window (``u`` = neighbor ids from ``indices``, ``w``
    = edge weights, ``eidx`` = the window's positions in the edge arrays,
    padding masked).  Both passes evaluate the (pure) hook on identical
    windows, so pass-2 crossings agree with pass-1 totals exactly.  Pure jnp,
    shared verbatim by both backends (the huge-degree tail of the bucketed
    window scheduler).  Returns per-row edge offsets, -1 for dead ends.
    Not jitted here: ``bias_of`` is a closure — callers jit the enclosing
    step.
    """
    start = indptr[cur]
    deg = indptr[cur + 1] - start
    nchunks = jnp.maximum((jnp.max(deg) + chunk - 1) // chunk, 1)
    max_iters = (weights.shape[0] + chunk - 1) // chunk

    def chunk_bias(c):
        offs = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        m = offs < deg[..., None]
        eidx = jnp.where(m, start[..., None] + offs, 0)
        u = jnp.where(m, indices[eidx], -1)
        w = jnp.where(m, weights[eidx], 0.0)
        return jnp.where(m, jnp.maximum(bias_of(u, w, m, eidx), 0.0), 0.0), m

    def p1_body(c, tot):
        def step(t):
            b, _ = chunk_bias(c)
            return t + jnp.sum(b, axis=-1)

        return jax.lax.cond(c < nchunks, step, lambda t: t, tot)

    total = jax.lax.fori_loop(0, max_iters, p1_body, jnp.zeros(cur.shape, jnp.float32))
    r = jax.random.uniform(key, cur.shape, dtype=jnp.float32) if rand is None else rand
    target = r * total

    def p2_body(c, carry):
        def step(args):
            cum, found = args
            b, m = chunk_bias(c)
            cw = jnp.cumsum(b, axis=-1) + cum[..., None]
            hit = (cw > target[..., None]) & m & (found[..., None] < 0)
            any_hit = jnp.any(hit, axis=-1)
            first = jnp.argmax(hit, axis=-1) + c * chunk
            found = jnp.where((found < 0) & any_hit, first, found)
            return cw[..., -1], found

        return jax.lax.cond(c < nchunks, step, lambda a: a, carry)

    cum0 = jnp.zeros(cur.shape, jnp.float32)
    found0 = jnp.full(cur.shape, -1, jnp.int32)
    _, found = jax.lax.fori_loop(0, max_iters, p2_body, (cum0, found0))
    found = jnp.where((found < 0) & (deg > 0) & (total > 0), deg - 1, found)
    return jnp.where((deg > 0) & (total > 0), found, -1)


# ---------------------------------------------------------------------------
# Alias tables (Vose) and rejection sampling — the adaptive selection
# runtime's O(1) draw methods (DESIGN.md §13).  Construction is host-side
# numpy (once per (graph, FlatBias)); draws are pure jnp, shared verbatim by
# the reference backend and the Pallas kernels' tails, and mirrored exactly
# (same f32 arithmetic) by the kernels themselves.
# ---------------------------------------------------------------------------


def build_alias(indptr, bias) -> tuple[np.ndarray, np.ndarray]:
    """Per-row alias tables over a flat CSR bias array (Vose's method).

    Vectorized over rows grouped by exact degree: each group forms an
    ``(R, d)`` matrix and the small/large pairing loop retires one column per
    iteration for every row simultaneously.  Internally float64 so the
    probability identity ``prob[j] + sum(1 - prob[i] for alias[i] == j) ==
    d * bias[j] / total`` holds to f32 round-off after the final cast.

    Returns ``(prob, alias)``: ``prob`` float32 ``(E,)`` acceptance
    thresholds, ``alias`` int32 ``(E,)`` row-LOCAL redirect offsets.
    Zero-total rows get ``prob = 0`` / ``alias = -1`` (the draw reads the
    -1 as a dead end).  Deterministic: numpy argmax first-index tie-breaks.
    """
    indptr = np.asarray(indptr)
    bias = np.maximum(np.asarray(bias, dtype=np.float64), 0.0)
    e = bias.shape[0]
    deg = np.diff(indptr).astype(np.int64)
    prob_out = np.zeros(e, dtype=np.float32)
    alias_out = np.full(e, -1, dtype=np.int32)
    for d in np.unique(deg):
        if d <= 0:
            continue
        d = int(d)
        starts = indptr[:-1][deg == d].astype(np.int64)
        w = bias[starts[:, None] + np.arange(d)[None, :]]  # (R, d)
        tot = w.sum(axis=1)
        ok = tot > 0.0
        if not ok.any():
            continue
        starts, w, tot = starts[ok], w[ok], tot[ok]
        r = starts.shape[0]
        p = w * (d / tot[:, None])  # scaled to sum d
        alias = np.full((r, d), -1, dtype=np.int32)
        active = np.ones((r, d), dtype=bool)
        for _ in range(max(d - 1, 0)):
            small = active & (p < 1.0)
            large = active & (p >= 1.0)
            has = small.any(axis=1) & large.any(axis=1)
            if not has.any():
                break
            rows = np.nonzero(has)[0]
            s = np.argmax(small[rows], axis=1)  # first active small
            g = np.argmax(large[rows], axis=1)  # first active large
            alias[rows, s] = g
            active[rows, s] = False
            p[rows, g] -= 1.0 - p[rows, s]
        # leftovers (all-large or all-small residue): certain acceptance
        lr, lc = np.nonzero(active)
        p[lr, lc] = 1.0
        alias[lr, lc] = lc
        flat = (starts[:, None] + np.arange(d)[None, :]).ravel()
        prob_out[flat] = p.astype(np.float32).ravel()
        alias_out[flat] = alias.ravel()
    return prob_out, alias_out


def build_row_max(indptr, bias) -> np.ndarray:
    """Per-vertex max bias, ``(V,)`` float32 — the rejection envelope."""
    indptr = np.asarray(indptr)
    bias = np.maximum(np.asarray(bias, dtype=np.float64), 0.0)
    deg = np.diff(indptr)
    if bias.shape[0] == 0:
        return np.zeros(deg.shape[0], dtype=np.float32)
    starts = np.minimum(indptr[:-1], bias.shape[0] - 1)
    rm = np.maximum.reduceat(bias, starts)
    return np.where(deg > 0, rm, 0.0).astype(np.float32)


def rejection_randoms(key: jax.Array, batch_shape: tuple, iters: int = REJECT_ITERS) -> jax.Array:
    """Pre-generated rejection budget: ``(..., iters, 2)`` uniforms.

    Round ``t`` consumes ``uniform(fold_in(key, 2t))`` for the candidate
    slot and ``uniform(fold_in(key, 2t + 1))`` for the accept test — the
    counted-RNG contract shared by the reference draw, the Pallas kernel,
    and the sharded drain's instance-indexed streams (change all or none).
    """
    if iters < 1:
        raise ValueError(f"rejection budget needs at least one round, got iters={iters}")
    rs = [
        jax.random.uniform(jax.random.fold_in(key, t), tuple(batch_shape), dtype=jnp.float32)
        for t in range(2 * iters)
    ]
    return jnp.stack(rs, axis=-1).reshape(tuple(batch_shape) + (iters, 2))


def alias_draw_flat(
    starts: jax.Array,
    degs: jax.Array,
    prob: jax.Array,
    alias: jax.Array,
    indices: jax.Array,
    rand: jax.Array,
    *,
    cap: int | None = None,
) -> jax.Array:
    """One O(1) alias draw per walker from flat CSR-aligned tables.

    ``rand`` is the SAME single uniform an ITS cohort would consume (each
    walker lives in exactly one cohort, so the streams never collide).
    ``cap`` truncates rows to the bucket segment exactly like the kernels'
    2-block window does for absorbed oversized rows (understated
    ``max_degree``): slots and alias redirects clamp into ``[0, cap)`` so
    reference and Pallas stay bit-identical even in that degenerate case.
    Returns next vertices (int32), -1 for dead ends (zero-total rows carry
    ``alias = -1``).
    """
    deg_eff = degs if cap is None else jnp.minimum(degs, cap)
    u = rand * deg_eff.astype(jnp.float32)
    slot = jnp.minimum(u.astype(jnp.int32), jnp.maximum(deg_eff - 1, 0))
    frac = u - slot.astype(jnp.float32)
    pos = jnp.clip(starts + slot, 0, prob.shape[0] - 1)
    a = alias[pos]
    chosen = jnp.where(frac < prob[pos], slot, a)
    chosen = jnp.clip(chosen, 0, jnp.maximum(deg_eff - 1, 0))
    nxt = indices[jnp.clip(starts + chosen, 0, indices.shape[0] - 1)]
    dead = (degs <= 0) | (a < 0)
    return jnp.where(dead, -1, nxt).astype(jnp.int32)


def rejection_draw_flat(
    starts: jax.Array,
    degs: jax.Array,
    flat_bias: jax.Array,
    row_max: jax.Array,
    indices: jax.Array,
    rej: jax.Array,
    *,
    cap: int | None = None,
) -> jax.Array:
    """Counted-RNG rejection draw per walker over flat CSR bias.

    ``rej`` is the ``(..., iters, 2)`` budget from
    :func:`rejection_randoms`; ``row_max`` is each walker's envelope (its
    row's max bias, gathered by the caller).  Round ``t`` proposes
    ``slot = floor(r_slot * deg)`` and accepts iff
    ``r_acc * row_max < bias[slot]`` — first acceptance wins; an exhausted
    budget falls back to the last candidate if it carries mass.  Static
    unroll (iters is a compile-time constant), bit-identical to the Pallas
    kernel's loop.
    """
    iters = rej.shape[-2]
    deg_eff = degs if cap is None else jnp.minimum(degs, cap)
    degf = deg_eff.astype(jnp.float32)
    chosen = jnp.full(degs.shape, -1, jnp.int32)
    done = jnp.zeros(degs.shape, bool)
    last = jnp.zeros(degs.shape, jnp.int32)
    last_b = jnp.zeros(degs.shape, jnp.float32)
    for t in range(iters):
        slot = jnp.minimum((rej[..., t, 0] * degf).astype(jnp.int32), jnp.maximum(deg_eff - 1, 0))
        bval = flat_bias[jnp.clip(starts + slot, 0, flat_bias.shape[0] - 1)]
        acc = rej[..., t, 1] * row_max < bval
        chosen = jnp.where(~done & acc, slot, chosen)
        last, last_b = slot, bval
        done = done | acc
    chosen = jnp.where(done, chosen, jnp.where(last_b > 0, last, -1))
    nxt = indices[jnp.clip(starts + jnp.maximum(chosen, 0), 0, indices.shape[0] - 1)]
    dead = (degs <= 0) | (row_max <= 0) | (chosen < 0)
    return jnp.where(dead, -1, nxt).astype(jnp.int32)
