"""Fault-tolerant sharded checkpointing (no orbax on this container).

Layout:  <dir>/step_<N>/  with one ``.npy`` per leaf (path-encoded names) +
``manifest.json`` (step, leaf index, config fingerprint, mesh shape).
Guarantees:
  - atomic: written to ``step_<N>.tmp`` then ``os.rename`` (restart never
    sees a torn checkpoint);
  - keep-k garbage collection;
  - async: ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread — training continues;
  - elastic restore: arrays are loaded host-side and ``device_put`` with the
    *target* sharding, so a checkpoint written on one mesh restores onto any
    other (device-count changes included).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, fingerprint: str = ""):
        self.directory = directory
        self.keep = keep
        self.fingerprint = fingerprint
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- writing -----------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        host = [np.asarray(x) for x in _flatten(tree)[0]]
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        self.wait()  # one in-flight save at a time
        host = [np.asarray(x) for x in _flatten(tree)[0]]  # snapshot now
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, extra: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, arr in enumerate(host_leaves):
            np.save(os.path.join(tmp, _leaf_name(i)), arr)
        manifest = {
            "step": step,
            "num_leaves": len(host_leaves),
            "fingerprint": self.fingerprint,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- reading -----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.directory)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None, shardings: Any = None):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding (target mesh) — this
        is the elastic-rescale path: host arrays are placed directly with the
        new sharding regardless of the mesh that wrote them.
        Returns (tree, manifest).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if self.fingerprint and manifest["fingerprint"] != self.fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']!r} != expected {self.fingerprint!r}"
            )
        flat_t, treedef = _flatten(template)
        leaves = []
        flat_s = _flatten(shardings)[0] if shardings is not None else [None] * len(flat_t)
        for i, (t, s) in enumerate(zip(flat_t, flat_s)):
            arr = np.load(os.path.join(d, _leaf_name(i)))
            if hasattr(t, "dtype"):
                arr = arr.astype(t.dtype)
            if s is not None:
                leaves.append(jax.device_put(arr, s))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
