"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

Assigned: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.
xLSTM[7:1]: pattern of 7 mLSTM + 1 sLSTM per 8 layers (paper's LM ratio);
blocks carry their own projections (d_ff=0).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    glu=False,
    activation="gelu",
    tie_embeddings=True,
    optimizer="adamw",
    # §Perf xlstm iterations: TP is pure overhead at 350M — remap model
    # axis to data parallelism; single loss chunk; bf16 reduces (TPU)
    tp_mode="dp",
    microbatches=1,
    loss_chunk=4096,
    reduce_dtype="bf16",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=256,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    glu=False,
    activation="gelu",
    dtype="float32",
    param_dtype="float32",
    attn_chunk=32,
    remat="none",
)
