"""Microbenchmark: reference vs Pallas ITS selection → BENCH_select.json.

Times the backend dispatcher's two routes on identical inputs (same counted
RNG budget, so both compute the same selections) across several
(instances, pool, k) shapes, and records wall times so the perf trajectory
is measurable PR-over-PR.  On non-TPU hosts the Pallas route runs in
interpret mode — that times the interpreter, not the kernel — so it is
SKIPPED by default there (rows carry ``pallas_interpret`` /
``pallas_skipped`` tags); ``--include-interpret`` restores it.  The number
that matters is the ratio on TPU, where the kernel fuses CTPS build +
search + BRS retry in VMEM.

Usage:  PYTHONPATH=src python benchmarks/bench_select.py [--iters 8]
        [--skip-interpret | --include-interpret]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import timeit  # noqa: E402

from repro.core import backend as bk  # noqa: E402

# (instances, pool size, draws) — frontier-select-like, neighbor-select-like,
# and a wide-pool layer-sampling shape; pools deliberately not lane-aligned
# so the dispatcher's padding plumbing is on the timed path.
SHAPES = [
    (128, 256, 4),
    (256, 100, 2),
    (64, 1000, 8),
]

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_select.json"


def bench_shape(i_dim, p, k, max_iters, skip_pallas):
    key = jax.random.PRNGKey(i_dim * p + k)
    b = jax.random.uniform(key, (i_dim, p))

    def run(backend):
        @jax.jit
        def fn(key, b):
            return bk.select_without_replacement(
                key, b, None, k, method="its_brs", backend=backend, max_iters=max_iters
            ).indices
        return timeit(fn, key, b, warmup=1, iters=3)

    t_ref = run("reference")
    t_pal = None if skip_pallas else run("pallas")
    return {
        "instances": i_dim,
        "pool": p,
        "k": k,
        "max_iters": max_iters,
        "reference_s": t_ref,
        "pallas_s": t_pal,
        "speedup": t_ref / t_pal if t_pal else None,
        "pallas_interpret": jax.default_backend() != "tpu",
        "pallas_skipped": skip_pallas,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8, help="retry budget (rounds)")
    ap.add_argument("--skip-interpret", dest="skip_interpret",
                    action="store_true", default=None,
                    help="skip the interpret-mode Pallas timing (default on non-TPU)")
    ap.add_argument("--include-interpret", dest="skip_interpret",
                    action="store_false",
                    help="time the interpret-mode Pallas route anyway")
    args = ap.parse_args()
    on_tpu = jax.default_backend() == "tpu"
    skip = args.skip_interpret
    if skip is None:
        skip = not on_tpu
    skip_pallas = skip and not on_tpu

    rows = []
    for i_dim, p, k in SHAPES:
        row = bench_shape(i_dim, p, k, args.iters, skip_pallas)
        rows.append(row)
        pal = (
            f"pallas {row['pallas_s']*1e3:8.2f} ms   speedup {row['speedup']:.2f}x"
            if row["pallas_s"] is not None
            else "pallas    skipped (interpret mode)"
        )
        print(
            f"I={i_dim:5d} P={p:5d} k={k:2d}  "
            f"reference {row['reference_s']*1e3:8.2f} ms   " + pal
        )

    payload = {
        "bench": "its_brs selection, reference vs pallas backend",
        "device": jax.default_backend(),
        "pallas_interpret": not on_tpu,
        "skip_interpret": skip,
        "results": rows,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
