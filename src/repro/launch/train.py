"""Production training launcher.

    python -m repro.launch.train --arch internlm2-1.8b --steps 1000 \
        --batch 32 --seq 128 --smoke --ckpt-dir /ckpts/run1 [--data walks]

Composes the full stack: mesh construction (elastic: built from whatever
devices are visible), C-SAW walk-corpus or synthetic data, pjit'd train step
(per-arch sharding rules, microbatching, optional compressed pod gradients),
async fault-tolerant checkpoints with restart-from-latest, straggler monitor.

``--smoke`` selects the reduced config (CPU-runnable); omit it on a real
TPU fleet to train the exact assigned architecture.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StepMonitor
from repro.train.optimizer import OptConfig, opt_init
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 pod mesh (requires 256 devices)")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--compressed", action="store_true",
                    help="int8 gradient reduction over the pod axis")
    ap.add_argument("--data", choices=("synthetic", "walks"), default="synthetic")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multipod)
    else:
        mesh = make_host_mesh()
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e9:.2f}B mesh={dict(mesh.shape)}")

    corpus = None
    if args.data == "walks":
        from repro.data.walk_corpus import build_walk_corpus
        from repro.graph import powerlaw_graph

        g = powerlaw_graph(min(cfg.vocab_size, 20_000), seed=0, weighted=True)
        corpus = build_walk_corpus(
            g, num_walks=4096, walk_length=args.seq, vocab_size=cfg.vocab_size,
            max_degree=min(g.max_degree(), 512),
        )
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, corpus=corpus,
                         host_index=jax.process_index(), host_count=jax.process_count())

    ocfg = OptConfig(kind=cfg.optimizer, lr=args.lr)
    step_fn, _ = make_train_step(
        cfg, ocfg, mesh, compressed=args.compressed, global_batch=args.batch
    )
    mgr = CheckpointManager(args.ckpt_dir, keep=3, fingerprint=cfg.name)
    monitor = StepMonitor()

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_init(ocfg, params)
    step = jnp.zeros((), jnp.int32)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start = manifest["step"]
        pipe.load_state_dict(manifest["extra"]["pipeline"])
        step = jnp.asarray(start, jnp.int32)
        print(f"restarted from step {start}")

    loss = float("nan")
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        t0 = time.perf_counter()
        params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
        loss = float(metrics["loss"])
        if monitor.observe(i, time.perf_counter() - t0):
            print(f"step {i}: straggler — early checkpoint")
            mgr.save(i, (params, opt_state), extra={"pipeline": pipe.state_dict()})
        if i % args.ckpt_every == 0 and i > start:
            mgr.save_async(i, (params, opt_state), extra={"pipeline": pipe.state_dict()})
        if i % args.log_every == 0:
            print(f"step {i:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"({monitor.median*1e3:.0f} ms/step)")
    mgr.wait()
    mgr.save(args.steps, (params, opt_state), extra={"pipeline": pipe.state_dict()})
    print(f"finished at step {args.steps}, loss {loss:.4f}")


if __name__ == "__main__":
    main()
