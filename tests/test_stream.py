"""Streaming sampling service (repro.serve.stream).

CI-blocking contracts:

- the batching-window policy launches for the right *reason*: fill when a
  cohort hits ``max_requests_per_launch``, slack when a deadline'd
  member's remaining budget approaches the measured launch cost, window
  when a deadline-less member has waited ``max_batch_window_ms``;
- launch order is EDF with priority tiers breaking ties;
- streamed results are bit-identical to the standalone padded engine call
  (streaming changes launch timing, never packing semantics);
- per-tenant token buckets reject over-quota submits with an
  :class:`AdmissionError` naming the violated limit;
- a failed cohort launch fails exactly its unserved members' futures,
  with a :class:`DrainError` carrying the partial results.

Everything except the thread-mode smoke runs in the deterministic driving
mode: ``start=False`` + an injected fake clock + ``poll()``/``flush()``,
so every policy decision is replayable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.graph import powerlaw_graph
from repro.graph.partition import partition_by_vertex_range
from repro.serve import (
    AdmissionError,
    DrainError,
    Priority,
    SamplingService,
    ServiceConfig,
    StreamConfig,
    StreamingSamplingService,
    TenantQuota,
)
from repro.serve.queue import _pow2_bucket


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(400, seed=3, weighted=True)


class FakeClock:
    """Injectable monotonic clock: time moves only when the test says so."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_stream(graph, config=None, svc_config=None, **svc_kwargs):
    clk = FakeClock()
    svc = SamplingService(
        graph, backend="reference", key=jax.random.PRNGKey(7),
        config=svc_config, **svc_kwargs,
    )
    stream = StreamingSamplingService(svc, config, clock=clk, start=False)
    return stream, clk


class TestWindowPolicy:
    def test_window_trigger(self, graph):
        """Deadline-less requests wait exactly the batching window, then
        launch together (one cohort, reason "window")."""
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=20))
        f1 = stream.submit([0, 1, 2], depth=4, spec=alg.deepwalk())
        clk.t = 0.005
        f2 = stream.submit([3, 4], depth=4, spec=alg.deepwalk())
        clk.t = 0.019  # window not elapsed for either
        assert stream.poll() == 0 and stream.pending == 2
        clk.t = 0.0201  # f1's window elapsed; f2 rides along (same cohort)
        assert stream.poll() == 1
        assert stream.pending == 0 and f1.done() and f2.done()
        assert f1.latency.reason == "window"
        assert f1.result().walks.shape == (3, 5)
        assert f2.result().walks.shape == (2, 5)

    def test_fill_trigger(self, graph):
        """A cohort that reaches max_requests_per_launch launches at once —
        waiting longer buys nothing."""
        stream, clk = make_stream(
            graph, StreamConfig(max_batch_window_ms=1000),
            svc_config=ServiceConfig(max_requests_per_launch=3),
        )
        futs = [stream.submit([i], depth=4, spec=alg.deepwalk()) for i in range(3)]
        assert stream.poll() == 1  # no clock advance needed
        assert all(f.done() for f in futs)
        assert futs[0].latency.reason == "fill"

    def test_slack_trigger(self, graph):
        """A deadline'd request launches when its remaining slack shrinks to
        slack_factor x the estimated launch cost — not before."""
        stream, clk = make_stream(
            graph,
            StreamConfig(
                max_batch_window_ms=1000, slack_factor=2.0,
                launch_cost_prior_ms=10.0,
            ),
        )
        f = stream.submit([0, 1], depth=4, spec=alg.deepwalk(), deadline_ms=100)
        clk.t = 0.079  # launch point is 100ms - 2x10ms = 80ms
        assert stream.poll() == 0
        clk.t = 0.081
        assert stream.poll() == 1
        assert f.latency.reason == "slack"
        assert f.latency.deadline_met is True

    def test_loose_deadline_overrides_window(self, graph):
        """An explicit deadline looser than the window keeps the request
        batching past max_batch_window_ms (the window is the *implied* SLO,
        not a cap on explicit ones)."""
        stream, clk = make_stream(
            graph,
            StreamConfig(
                max_batch_window_ms=20, slack_factor=1.0,
                launch_cost_prior_ms=10.0,
            ),
        )
        stream.submit([0], depth=4, spec=alg.deepwalk(), deadline_ms=500)
        clk.t = 0.100  # well past the window, well before 500ms - 10ms
        assert stream.poll() == 0
        clk.t = 0.491
        assert stream.poll() == 1

    def test_batching_false_launches_per_request(self, graph):
        """The open-loop baseline mode: every request launches immediately
        in its own cohort."""
        stream, clk = make_stream(
            graph, StreamConfig(batching=False, max_batch_window_ms=1000)
        )
        f1 = stream.submit([0, 1], depth=4, spec=alg.deepwalk())
        f2 = stream.submit([2, 3], depth=4, spec=alg.deepwalk())
        assert stream.poll() == 2  # no co-batching despite identical key
        assert f1.latency.reason == "immediate"
        assert f2.latency.reason == "immediate"
        assert stream.stats.stream_launches == 2

    def test_flush_launches_everything(self, graph):
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=1000))
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        assert stream.poll() == 0  # not due
        assert stream.flush() == 1
        assert f.latency.reason == "flush"


class TestLaunchOrder:
    def test_edf_across_cohorts(self, graph):
        """Among due cohorts, the earliest effective deadline launches
        first (module-level hook specs => distinct cohort keys)."""
        stream, clk = make_stream(
            graph, StreamConfig(slack_factor=1.0, launch_cost_prior_ms=1.0)
        )
        fa = stream.submit([0], depth=4, spec=alg.deepwalk(), deadline_ms=100)
        fb = stream.submit(
            [1], depth=4, spec=alg.weighted_random_walk(), deadline_ms=50
        )
        clk.t = 0.200  # both overdue
        assert stream.poll() == 2
        order = [lat.request_id for lat in stream.stats.stream_latencies]
        assert order == [fb.request_id, fa.request_id]

    def test_priority_breaks_deadline_ties(self, graph):
        """Equal deadlines: INTERACTIVE preempts STANDARD even though it
        arrived later."""
        stream, clk = make_stream(
            graph, StreamConfig(slack_factor=1.0, launch_cost_prior_ms=1.0)
        )
        fa = stream.submit([0], depth=4, spec=alg.deepwalk(), deadline_ms=50)
        fb = stream.submit(
            [1], depth=4, spec=alg.weighted_random_walk(), deadline_ms=50,
            priority=Priority.INTERACTIVE,
        )
        clk.t = 0.200
        assert stream.poll() == 2
        order = [lat.request_id for lat in stream.stats.stream_latencies]
        assert order == [fb.request_id, fa.request_id]
        assert fb.latency.tier == int(Priority.INTERACTIVE)

    def test_fifo_breaks_full_ties(self, graph):
        """Same deadline, same priority: arrival order decides."""
        stream, clk = make_stream(
            graph, StreamConfig(slack_factor=1.0, launch_cost_prior_ms=1.0)
        )
        fa = stream.submit([0], depth=4, spec=alg.deepwalk(), deadline_ms=50)
        fb = stream.submit(
            [1], depth=4, spec=alg.weighted_random_walk(), deadline_ms=50
        )
        clk.t = 0.200
        stream.poll()
        order = [lat.request_id for lat in stream.stats.stream_latencies]
        assert order == [fa.request_id, fb.request_id]


class TestStreamedParity:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_streamed_matches_standalone_padded_call(self, graph, backend):
        """The PR 4 guarantee, lifted to streaming: a streamed request's
        walks are bit-identical to the standalone ``random_walk`` call at
        the padded geometry, regardless of who shared its launch."""
        g = graph
        clk = FakeClock()
        svc = SamplingService(g, backend=backend, key=jax.random.PRNGKey(7))
        stream = StreamingSamplingService(
            svc, StreamConfig(max_batch_window_ms=10), clock=clk, start=False
        )
        rng = np.random.default_rng(5)
        subs = []
        for i in range(4):
            seeds = rng.integers(0, g.num_vertices, int(rng.integers(3, 20)))
            key = jax.random.fold_in(jax.random.PRNGKey(42), i)
            fut = stream.submit(
                seeds, depth=6, spec=alg.deepwalk(), key=key,
                deadline_ms=float(rng.integers(5, 100)),
            )
            subs.append((fut, seeds, key))
            clk.t += 0.003
        clk.t += 1.0
        stream.poll()
        cfg = svc.config
        for fut, seeds, key in subs:
            width = _pow2_bucket(len(seeds), cfg.min_walker_bucket)
            depth_b = _pow2_bucket(6, cfg.min_depth_bucket)
            row = np.full((width,), -1, np.int32)
            row[: len(seeds)] = seeds
            solo = random_walk(
                g, jnp.asarray(row), key, depth=depth_b, spec=alg.deepwalk(),
                max_degree=g.max_degree(), backend=backend,
            )
            expect = np.asarray(solo.walks)[: len(seeds), :7]
            np.testing.assert_array_equal(fut.result().walks, expect)


class TestQuota:
    def test_over_quota_rejected_with_named_limit(self, graph):
        stream, clk = make_stream(
            graph,
            StreamConfig(
                tenant_quotas={"acme": TenantQuota(walkers_per_s=10, burst_walkers=20)}
            ),
        )
        stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="acme")
        with pytest.raises(AdmissionError) as ei:
            stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="acme")
        msg = str(ei.value)
        assert "tenant_quotas['acme'].walkers_per_s=10" in msg
        assert "burst_walkers=20" in msg
        assert stream.stats.stream_quota_rejections == 1
        # unmetered tenants (and tenant-less requests) are unaffected
        stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="other")
        stream.submit(np.arange(16), depth=4, spec=alg.deepwalk())
        assert stream.pending == 3
        stream.flush()

    def test_bucket_refills_over_time(self, graph):
        stream, clk = make_stream(
            graph,
            StreamConfig(
                tenant_quotas={"t": TenantQuota(walkers_per_s=100, burst_walkers=16)}
            ),
        )
        stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="t")
        with pytest.raises(AdmissionError):
            stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="t")
        clk.t = 0.16  # 100 walkers/s x 0.16s = 16 tokens back
        stream.submit(np.arange(16), depth=4, spec=alg.deepwalk(), tenant="t")
        assert stream.pending == 2
        stream.flush()

    def test_backpressure_limits_apply_to_backlog(self, graph):
        stream, clk = make_stream(
            graph, StreamConfig(max_batch_window_ms=1000),
            svc_config=ServiceConfig(max_pending_requests=2),
        )
        stream.submit([0], depth=4, spec=alg.deepwalk())
        stream.submit([1], depth=4, spec=alg.deepwalk())
        with pytest.raises(AdmissionError, match="max_pending_requests=2"):
            stream.submit([2], depth=4, spec=alg.deepwalk())
        stream.flush()  # launching frees capacity
        stream.submit([2], depth=4, spec=alg.deepwalk())
        stream.flush()


class TestDelivery:
    def test_partial_failure_isolates_members(self, graph, monkeypatch):
        """Sequential-mode cohort: the member served before the failure gets
        its result; the failing member's future raises a DrainError carrying
        the partial results; other cohorts are untouched."""
        stream, clk = make_stream(
            graph, svc_config=ServiceConfig(fuse=False)
        )
        f1 = stream.submit([0, 1], depth=4, spec=alg.deepwalk())
        f2 = stream.submit([2, 3], depth=4, spec=alg.deepwalk())  # same cohort
        f3 = stream.submit([4, 5], depth=4, spec=alg.node2vec())  # separate
        import repro.serve.service as service_mod

        real = service_mod.random_walk
        calls = {"n": 0}

        def flaky(*args, **kwargs):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("injected launch failure")
            return real(*args, **kwargs)

        monkeypatch.setattr(service_mod, "random_walk", flaky)
        stream.flush()
        assert f1.result().walks.shape == (2, 5)  # served before the failure
        with pytest.raises(DrainError) as ei:
            f2.result()
        assert "1/2 cohort members completed" in str(ei.value)
        assert sorted(ei.value.completed) == [f1.request_id]
        assert isinstance(ei.value.__cause__, RuntimeError)
        assert f3.result().walks.shape == (2, 5)  # other cohort unaffected
        assert stream.stats.stream_failed_requests == 1

    def test_fused_failure_fails_whole_cohort_only(self, graph, monkeypatch):
        stream, clk = make_stream(graph)
        f1 = stream.submit([0, 1], depth=4, spec=alg.deepwalk())
        f2 = stream.submit([2, 3], depth=4, spec=alg.node2vec())
        import repro.serve.service as service_mod

        def boom(*args, **kwargs):
            raise RuntimeError("injected launch failure")

        monkeypatch.setattr(service_mod, "random_walk_segments", boom)
        stream.flush()
        # both cohorts route through the (broken) fused entry point; each
        # failure is scoped to its own cohort and carries no partial results
        for f in (f1, f2):
            exc = f.exception()
            assert isinstance(exc, DrainError)
            assert "0/1 cohort members completed" in str(exc)
            assert exc.completed == {}
        assert stream.stats.stream_failed_requests == 2

    def test_done_callbacks(self, graph):
        stream, clk = make_stream(graph)
        seen = []
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        f.add_done_callback(lambda fut: seen.append(("pre", fut.request_id)))
        stream.flush()
        f.add_done_callback(lambda fut: seen.append(("post", fut.request_id)))
        assert seen == [("pre", f.request_id), ("post", f.request_id)]

    def test_result_timeout(self, graph):
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=1000))
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        with pytest.raises(TimeoutError):
            f.result(timeout=0.01)
        stream.flush()
        assert f.result(timeout=0).walks.shape == (1, 5)


class TestLifecycle:
    def test_close_flush_serves_backlog(self, graph):
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=1000))
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        stream.close()
        assert f.result(timeout=0).walks.shape == (1, 5)

    def test_close_without_flush_cancels(self, graph):
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=1000))
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        stream.close(flush=False)
        with pytest.raises(DrainError, match="cancelled"):
            f.result(timeout=0)
        assert stream.pending == 0

    def test_submit_after_close_rejected(self, graph):
        stream, clk = make_stream(graph)
        stream.close()
        with pytest.raises(AdmissionError, match="closed"):
            stream.submit([0], depth=4, spec=alg.deepwalk())


class TestLatencyAccounting:
    def test_queue_and_total_latency_from_clock(self, graph):
        stream, clk = make_stream(graph, StreamConfig(max_batch_window_ms=1000))
        f = stream.submit([0], depth=4, spec=alg.deepwalk())
        clk.t = 0.050
        stream.flush()
        lat = f.latency
        assert lat.queue_ms == pytest.approx(50.0)
        assert lat.total_ms == pytest.approx(50.0)  # fake clock: 0ms launch
        assert lat.deadline_met is None
        assert stream.stats.stream_requests == 1
        assert stream.stats.stream_launches == 1
        assert stream.stats.stream_latencies == [lat]

    def test_deadline_miss_counted(self, graph):
        stream, clk = make_stream(graph)
        f = stream.submit([0], depth=4, spec=alg.deepwalk(), deadline_ms=10)
        clk.t = 1.0  # poll far too late: result lands past the deadline
        stream.poll()
        assert f.latency.deadline_met is False
        assert stream.stats.stream_deadline_misses == 1
        assert f.result(timeout=0).walks.shape == (1, 5)  # still served

    def test_launch_cost_ema(self, graph, monkeypatch):
        """The slack trigger's cost estimate tracks measured launch wall
        time per cohort key (EMA, alpha=0.25 here)."""
        stream, clk = make_stream(
            graph, StreamConfig(launch_cost_prior_ms=25.0, launch_cost_alpha=0.25)
        )
        svc = stream._svc
        real = svc._run_cohort
        advance = {"by": 0.008}

        def timed(cohort, out):
            clk.t += advance["by"]
            return real(cohort, out)

        monkeypatch.setattr(svc, "_run_cohort", timed)
        spec = alg.deepwalk()
        assert stream.launch_cost_ms(spec, depth=4, width=1) == pytest.approx(25.0)
        stream.submit([0], depth=4, spec=spec)
        stream.flush()
        assert stream.launch_cost_ms(spec, depth=4, width=1) == pytest.approx(8.0)
        advance["by"] = 0.004
        stream.submit([1], depth=4, spec=spec)
        stream.flush()
        # EMA: 0.25 x 4ms + 0.75 x 8ms = 7ms
        assert stream.launch_cost_ms(spec, depth=4, width=1) == pytest.approx(7.0)


class TestPlacements:
    def test_oom_streaming_merges_depths(self, graph):
        """Partitioned placement: streamed heterogeneous-depth requests of
        one program share a single frontier-queue drain."""
        g = graph
        parts = partition_by_vertex_range(g, 4)
        clk = FakeClock()
        svc = SamplingService(
            partitions=parts, total_vertices=g.num_vertices,
            backend="reference", oom_chunk=128,
        )
        stream = StreamingSamplingService(svc, clock=clk, start=False)
        fa = stream.submit(np.arange(30), depth=4, spec=alg.deepwalk())
        fb = stream.submit(np.arange(20), depth=9, spec=alg.deepwalk())
        clk.t = 1.0
        assert stream.poll() == 1
        assert svc.stats.oom_launches == 1
        assert fa.result(timeout=0).walks.shape == (30, 5)
        assert fb.result(timeout=0).walks.shape == (20, 10)

    def test_sharded_streaming(self, graph):
        g = graph
        mesh = jax.make_mesh((1,), ("data",))
        clk = FakeClock()
        svc = SamplingService(
            g, mesh=mesh, placement="sharded", backend="reference",
        )
        stream = StreamingSamplingService(svc, clock=clk, start=False)
        f = stream.submit(np.arange(16), depth=5, spec=alg.deepwalk())
        clk.t = 1.0
        assert stream.poll() == 1
        assert svc.stats.sharded_launches == 1
        assert f.result(timeout=0).walks.shape == (16, 6)


class TestThreadMode:
    def test_background_scheduler_serves_bursts(self, graph):
        """The production mode: a daemon thread drives the same policy.
        Real clock — only liveness and delivery are asserted here; policy
        details are covered by the deterministic tests above."""
        g = graph
        svc = SamplingService(g, backend="reference", key=jax.random.PRNGKey(3))
        with StreamingSamplingService(
            svc, StreamConfig(max_batch_window_ms=5)
        ) as stream:
            futs = [
                stream.submit(
                    [i, i + 1], depth=4, spec=alg.deepwalk(),
                    deadline_ms=30_000,
                )
                for i in range(4)
            ]
            for f in futs:
                assert f.result(timeout=120).walks.shape == (2, 5)
        assert stream.pending == 0
        assert stream.stats.stream_requests == 4
        assert len(stream.stats.stream_latencies) == 4
