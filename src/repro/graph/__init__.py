"""Graph substrate: CSR storage, generators, partitioning."""
from repro.graph.csr import CSRGraph, csr_from_edges, degrees, neighbors_padded
from repro.graph.generators import rmat_graph, erdos_renyi_graph, powerlaw_graph
from repro.graph.partition import (
    DevicePartition,
    PartitionMap,
    RangePartition,
    partition_by_vertex_range,
    partition_of,
)

__all__ = [
    "CSRGraph",
    "csr_from_edges",
    "degrees",
    "neighbors_padded",
    "rmat_graph",
    "erdos_renyi_graph",
    "powerlaw_graph",
    "DevicePartition",
    "PartitionMap",
    "RangePartition",
    "partition_by_vertex_range",
    "partition_of",
]
