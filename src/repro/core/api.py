"""C-SAW user programming interface (paper Fig. 2(a)).

Users express a sampling / random-walk algorithm with three hooks, all
batched (arrays carry leading instance dims) and jit-traceable:

  - ``vertex_bias(VertexCtx) -> biases``  : bias of each FrontierPool candidate
  - ``edge_bias(EdgeCtx) -> biases``      : bias of each candidate neighbor
  - ``update(key, EdgeCtx, u) -> vertex`` : vertex to insert into the pool
                                            (jump/restart/MH live here)

Everything else — CTPS construction, ITS selection, BRS collision handling,
frontier queues, partitioning, multi-device — is the framework's job.

Specs may additionally *declare* what their hooks consume as a
``core.transition.TransitionProgram`` (``transition=`` field): the engines
dispatch on the lowered program, compiling flat and window biases plus
declarative update epilogues onto the degree-bucketed fast path
(DESIGN.md §10) instead of interpreting opaque callables through the dense
full-context gather.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class VertexCtx(NamedTuple):
    """Context for VERTEXBIAS: candidates of a frontier pool."""

    v: jax.Array  # (..., C) candidate vertex ids (-1 = empty slot)
    deg: jax.Array  # (..., C) degrees
    depth: jax.Array  # () current iteration


class EdgeCtx(NamedTuple):
    """Context for EDGEBIAS/UPDATE: edges (v -> u) out of the frontier."""

    v: jax.Array  # (...,) source/frontier vertex
    u: jax.Array  # (..., D) candidate neighbors (-1 = padding)
    weight: jax.Array  # (..., D) edge weights
    deg_v: jax.Array  # (...,)
    deg_u: jax.Array  # (..., D)
    prev: jax.Array  # (...,) vertex visited before v (-1 at start)
    is_prev_neighbor: Optional[jax.Array]  # (..., D) bool, only if requested
    depth: jax.Array  # ()


BiasFn = Callable[[VertexCtx], jax.Array]
EdgeBiasFn = Callable[[EdgeCtx], jax.Array]
UpdateFn = Callable[[jax.Array, EdgeCtx, jax.Array], jax.Array]
# graph -> (E,) per-edge bias in CSR order, for the compiled walk fast path
FlatEdgeBiasFn = Callable[[object], jax.Array]


def uniform_vertex_bias(ctx: VertexCtx) -> jax.Array:
    """Constant VERTEXBIAS: every frontier-pool candidate equally likely.

    >>> import jax.numpy as jnp
    >>> from repro.core.api import VertexCtx, uniform_vertex_bias
    >>> ctx = VertexCtx(v=jnp.array([3, 7]), deg=jnp.array([2, 5]),
    ...                 depth=jnp.int32(0))
    >>> uniform_vertex_bias(ctx)
    Array([1., 1.], dtype=float32)
    """
    return jnp.ones_like(ctx.v, dtype=jnp.float32)


def degree_vertex_bias(ctx: VertexCtx) -> jax.Array:
    """Degree-proportional VERTEXBIAS (MDRW frontier selection, paper Fig. 3b).

    >>> import jax.numpy as jnp
    >>> from repro.core.api import VertexCtx, degree_vertex_bias
    >>> ctx = VertexCtx(v=jnp.array([3, 7]), deg=jnp.array([2, 5]),
    ...                 depth=jnp.int32(0))
    >>> degree_vertex_bias(ctx)
    Array([2., 5.], dtype=float32)
    """
    return ctx.deg.astype(jnp.float32)


def _demo_edge_ctx():
    """A 1-walker, 3-candidate EdgeCtx shared by the doctests below."""
    return EdgeCtx(
        v=jnp.array([0]),
        u=jnp.array([[1, 2, -1]]),
        weight=jnp.array([[0.5, 2.0, 0.0]]),
        deg_v=jnp.array([2]),
        deg_u=jnp.array([[3, 1, 0]]),
        prev=jnp.array([-1]),
        is_prev_neighbor=None,
        depth=jnp.int32(0),
    )


def uniform_edge_bias(ctx: EdgeCtx) -> jax.Array:
    """Constant EDGEBIAS: unbiased neighbor choice (DeepWalk).

    >>> from repro.core.api import _demo_edge_ctx, uniform_edge_bias
    >>> uniform_edge_bias(_demo_edge_ctx())
    Array([[1., 1., 1.]], dtype=float32)
    """
    return jnp.ones_like(ctx.u, dtype=jnp.float32)


def weight_edge_bias(ctx: EdgeCtx) -> jax.Array:
    """Edge-weight EDGEBIAS: transition probability ∝ edge weight.

    >>> from repro.core.api import _demo_edge_ctx, weight_edge_bias
    >>> weight_edge_bias(_demo_edge_ctx())
    Array([[0.5, 2. , 0. ]], dtype=float32)
    """
    return ctx.weight.astype(jnp.float32)


def degree_edge_bias(ctx: EdgeCtx) -> jax.Array:
    """Biased DeepWalk: neighbor degree as bias (paper §II-A).

    >>> from repro.core.api import _demo_edge_ctx, degree_edge_bias
    >>> degree_edge_bias(_demo_edge_ctx())
    Array([[3., 1., 0.]], dtype=float32)
    """
    return ctx.deg_u.astype(jnp.float32)


def identity_update(key: jax.Array, ctx: EdgeCtx, u: jax.Array) -> jax.Array:
    """Default UPDATE: walk to the selected neighbor unchanged.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.api import _demo_edge_ctx, identity_update
    >>> identity_update(jax.random.PRNGKey(0), _demo_edge_ctx(), jnp.array([2]))
    Array([2], dtype=int32)
    """
    return u


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """A sampling/random-walk algorithm as bias hooks + structural knobs.

    The (frontier_size, neighbor_size, per_vertex, ...) knobs realize the
    paper's Table I design space.

    A custom algorithm is just hooks — here, transition bias ∝ weight²
    (every unset knob keeps its paper default):

    >>> import jax.numpy as jnp
    >>> from repro.core.api import EdgeCtx, SamplingSpec
    >>> spec = SamplingSpec(edge_bias=lambda ctx: jnp.square(ctx.weight),
    ...                     name="hot_edges", track_visited=False)
    >>> spec.name, spec.frontier_size, spec.neighbor_size
    ('hot_edges', 1, 1)

    An undeclared hook is opaque to the compiler, so the engines fall back
    to the dense full-context gather; declaring what the hook consumes
    (``transition=``) puts it on the degree-bucketed fast path:

    >>> from repro.core.transition import lower
    >>> lower(spec).mode
    'opaque'
    >>> from repro.core import algorithms as alg
    >>> lower(alg.node2vec()).mode, lower(alg.deepwalk()).mode
    ('window', 'flat')

    Flat-bias specs may pin the selection method instead of letting the
    cost model auto-pick per degree bucket (``selection_method``,
    DESIGN.md §13):

    >>> import dataclasses
    >>> pinned = dataclasses.replace(alg.deepwalk(), selection_method="alias")
    >>> lower(alg.deepwalk()).method, lower(pinned).method
    ('auto', 'alias')
    """

    vertex_bias: BiasFn = uniform_vertex_bias
    edge_bias: EdgeBiasFn = uniform_edge_bias
    update: UpdateFn = identity_update
    frontier_size: int = 1
    neighbor_size: int = 1
    # per-vertex pools (neighbor/forest-fire sampling) vs per-instance pooled
    # (layer sampling, MDRW) — see paper §IV-A "Inter-warp Parallelism".
    per_vertex: bool = True
    # MDRW replaces the selected frontier vertex by its sampled neighbor.
    replace_selected: bool = False
    # sampling-without-replacement across the whole instance (traversal
    # sampling); visited vertices get zero bias. Needs a (I, V) bitmap.
    track_visited: bool = True
    # node2vec needs to know whether u neighbors prev (costs a membership scan)
    needs_prev_neighbors: bool = False
    # forest fire: geometric NeighborSize with burning probability p_f
    burn_prob: Optional[float] = None
    # Compiled walk fast path (DESIGN.md §6): when the edge bias depends only
    # on static edge/endpoint features, provide it as a flat (E,) array in
    # CSR order so the degree-bucketed Pallas scheduler can sample straight
    # from the edge arrays, never materializing padded neighbor tensors.
    # Must satisfy flat_edge_bias(g)[e] == edge_bias(ctx) for every real edge
    # e.  None ⇒ state-dependent bias; backend="pallas" falls back to the
    # reference per-step selection (still kernel-dispatched).  On the fast
    # path, ``update`` hooks receive a rank-preserving minimal EdgeCtx whose
    # neighbor axis holds only the selected edge (D = 1) and whose
    # ``weight`` is a unit placeholder (the real edge weight is never
    # gathered) — update hooks that read ``ctx.weight`` must leave
    # flat_edge_bias unset to stay on the full-context path.
    flat_edge_bias: Optional[FlatEdgeBiasFn] = None
    # Declared transition program (``core.transition.TransitionProgram``):
    # the declarative lowering of the hooks above.  When set it takes
    # precedence over the legacy flags — ``core.transition.lower`` dispatches
    # the engines on it (flat/window biases run the degree-bucketed fast
    # path on every backend; declarative epilogues fuse into the shared
    # post-select step).  None ⇒ inferred from the legacy fields.  Typed as
    # ``object`` only to avoid a circular import; it must be a
    # TransitionProgram (or None).
    transition: Optional[object] = None
    # Selection-method override for the flat-bias fast path (DESIGN.md §13):
    # None defers to the transition program's ``method`` (default "auto" —
    # the cost model picks per degree bucket); "its"/"alias"/"rejection"
    # force one method for every bucket.  ``core.transition.lower`` stamps
    # the override onto the lowered program.
    selection_method: Optional[str] = None
    name: str = "custom"
