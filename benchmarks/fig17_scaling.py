"""Paper Fig. 17: multi-device scaling — instance-parallel AND graph-sharded.

Runs subprocesses with ``--xla_force_host_platform_device_count=N`` so the
parent process keeps its single-device view (per the dry-run isolation
rule).  Wall-clock on shared host cores is not a throughput claim — the
host devices time-slice the same physical cores — so the reported figures
are the *work and memory distribution*: instances per device for the
zero-comm instance-parallel mode, and per-device CSR bytes (∝ 1/D) plus
drain wall time for the owner-routed sharded mode (``repro.shard``,
DESIGN.md §12).  The sharded sweep is written to ``BENCH_shard.json`` so
the mesh-scaling trajectory is tracked across PRs: per-device graph bytes
must fall with D while the drain keeps walking the full pl50k edge set.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import jax

from benchmarks.common import row

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_shard.json"

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk

n = %d
g = powerlaw_graph(20000, exponent=2.1, seed=7, weighted=True)
mesh = jax.make_mesh((n,), ("data",))
key = jax.random.PRNGKey(0)
seeds = jax.random.randint(key, (4096,), 0, g.num_vertices)
md = min(g.max_degree(), 512)
run = lambda: instance_parallel_walk(mesh, g, seeds, key, depth=32,
                                     spec=alg.biased_random_walk(), max_degree=md)
jax.block_until_ready(run().walks)
t0 = time.perf_counter()
res = run()
jax.block_until_ready(res.walks)
secs = time.perf_counter() - t0
print(json.dumps({"devices": n, "secs": secs, "edges": int(res.sampled_edges)}))
"""

_CHILD_SHARDED = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.graph.partition import PartitionMap, partition_by_vertex_range
from repro.shard import sharded_random_walk

n = %d
g = powerlaw_graph(%d, exponent=2.1, seed=7, weighted=True)  # 50000 = BENCH_GRAPHS["pl50k"]
hub_bytes = %s  # None = default degree-aware hub budget, 0 = hubs off
mesh = jax.make_mesh((n,), ("data",))
key = jax.random.PRNGKey(0)
seeds = jax.random.randint(key, (2048,), 0, g.num_vertices)
md = g.max_degree()
# what one device holds: compact local-id CSR + aligned global-id edge
# array — same layout arithmetic sharded_random_walk stages
from repro.core import backend as bk
seg_big = max(bk.walk_bucket_plan(md)[0])
pm = PartitionMap.create(g.num_vertices, n)
parts = partition_by_vertex_range(g, n)
pad_e = max((p.edge_lo %% seg_big) + p.num_edges for p in parts)
# indptr + 4 edge arrays: local ids, global ids, weights, and the sliced
# flat bias (the benchmarked spec is flat-bias; window mode ships 3)
bytes_per_device = 4 * ((pm.range_size + 2) + 4 * pad_e)
run = lambda: sharded_random_walk(mesh, g, seeds, key, depth=32,
                                  spec=alg.biased_random_walk(), max_degree=md,
                                  hub_bytes=hub_bytes)
jax.block_until_ready(run().walks)  # compile + first drain
t0 = time.perf_counter()
res = run()
jax.block_until_ready(res.walks)
secs = time.perf_counter() - t0
st = res.stats or {}
print(json.dumps({"devices": n, "secs": secs, "edges": int(res.sampled_edges),
                  "bytes_per_device": int(bytes_per_device),
                  "local_edges_max": int(pad_e), "total_edges": int(g.num_edges),
                  "exchanged_entries": int(st.get("exchanged_entries", 0)),
                  "exchange_bytes": int(st.get("exchange_bytes", 0)),
                  "hub_hops": int(st.get("hub_hops", 0)),
                  "num_hubs": int(st.get("num_hubs", 0)),
                  "hub_replicated_edges": int(st.get("hub_replicated_edges", 0))}))
"""


def _child(code: str, timeout: int = 1800) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run() -> list[str]:
    rows = []
    for n in (1, 2, 4):
        d = _child(_CHILD % (n, n), timeout=900)
        rows.append(row(
            f"fig17/devices={n}", d["secs"] * 1e6,
            f"SEPS={d['edges']/d['secs']:.3e};inst_per_dev={4096//n}",
        ))

    results = []
    for n in (1, 2, 4, 8):
        d = _child(_CHILD_SHARDED % (max(n, 1), n, 50000, "None"))
        rows.append(row(
            f"fig17/sharded_devices={n}", d["secs"] * 1e6,
            f"SEPS={d['edges']/d['secs']:.3e};"
            f"MB_per_dev={d['bytes_per_device']/1e6:.1f};"
            f"exch_MB={d['exchange_bytes']/1e6:.2f};hubs={d['num_hubs']}",
        ))
        results.append({
            "devices": n,
            "seconds": d["secs"],
            "sampled_edges_per_s": d["edges"] / d["secs"],
            "bytes_per_device": d["bytes_per_device"],
            "local_edges_max": d["local_edges_max"],
            "total_edges": d["total_edges"],
            "exchanged_entries": d["exchanged_entries"],
            "exchange_bytes": d["exchange_bytes"],
            "hub_hops": d["hub_hops"],
            "num_hubs": d["num_hubs"],
            "hub_replicated_edges": d["hub_replicated_edges"],
        })

    # the tentpole's transfer-volume claim, isolated: same drain with the hub
    # region disabled (hub_bytes=0) — exchange bytes must be measurably
    # higher without replication at the shard counts where it matters
    hub_replication = []
    for n in (4, 8):
        d0 = _child(_CHILD_SHARDED % (n, n, 50000, "0"))
        dh = next(r for r in results if r["devices"] == n)
        rows.append(row(
            f"fig17/hub_ablation D={n}", d0["secs"] * 1e6,
            f"exch_MB_nohubs={d0['exchange_bytes']/1e6:.2f};"
            f"exch_MB_hubs={dh['exchange_bytes']/1e6:.2f}",
        ))
        hub_replication.append({
            "devices": n,
            "exchange_bytes_hubs": dh["exchange_bytes"],
            "exchange_bytes_nohubs": d0["exchange_bytes"],
            "exchanged_entries_hubs": dh["exchanged_entries"],
            "exchanged_entries_nohubs": d0["exchanged_entries"],
            "hub_hops": dh["hub_hops"],
            "num_hubs": dh["num_hubs"],
            "seconds_nohubs": d0["secs"],
        })

    # the distinguishing experiment for "step cost ∝ shard size": hold E/D
    # roughly constant while the FULL graph grows ~10x.  Forced host devices
    # execute the D shards serially on the same cores, so seconds/D is the
    # per-shard drain cost — it must stay flat while total edges explode
    # (the replicated-psum design's per-step cost grows with full V instead).
    const_shard = []
    for v, n in ((12500, 1), (25000, 2), (50000, 4), (100000, 8)):
        d = _child(_CHILD_SHARDED % (max(n, 1), n, v, "None"))
        per_shard = d["secs"] / n
        rows.append(row(
            f"fig17/const_shard V={v} D={n}", d["secs"] * 1e6,
            f"secs_per_shard={per_shard:.3f};edges_per_dev={d['total_edges']//n}",
        ))
        const_shard.append({
            "vertices": v,
            "devices": n,
            "total_edges": d["total_edges"],
            "edges_per_device": d["total_edges"] // n,
            "seconds": d["secs"],
            "seconds_per_shard": per_shard,
        })
    payload = {
        "bench": "owner-routed sharded walk scaling (pl50k, 2048 walkers, depth 32)",
        "device": jax.default_backend(),
        "note": "forced host devices share physical cores (wall time is not a "
                "multi-chip throughput claim): bytes_per_device is the scaling "
                "metric of the device sweep, and seconds_per_shard of the "
                "constant-shard sweep must stay flat from D=2 up while "
                "total_edges grows ~10x (D=1 pays no exchange collective, so "
                "it sits lower) — scan-step cost tracks shard size, not "
                "full-graph size",
        "results": results,
        "hub_replication": hub_replication,
        "constant_shard_scaling": const_shard,
    }
    problems = check(payload)
    if problems:
        raise RuntimeError("scaling gate failed on fresh run:\n" + "\n".join(problems))
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


#: constant-shard seconds/shard may drift this far above the D=2 point before
#: the gate trips (ISSUE: per-shard drain cost must stay flat as the full
#: graph grows ~10x with E/D held constant).  Forced host devices time-slice
#: the same physical cores, so D=8 pays real contention even when the
#: per-shard work is constant — 2.0x tolerates that while still catching a
#: cost term that scales with full-graph size (which would show ~4x here).
CONST_SHARD_TOL = 2.0
#: device-sweep bytes_per_device must fall at least this much per doubling —
#: THE scaling claim of the device sweep (see the payload note: wall time on
#: forced host devices is not a multi-chip throughput claim).  Pure range
#: sharding gives ~0.50x; the replicated hub region rides on top, but its
#: default budget also halves with D, so 0.65x leaves honest headroom.
BYTES_STEP_TOL = 0.65
#: device-sweep sampled_edges/s cliff-guard.  Host devices share cores, so
#: SEPS structurally falls with D (both the pre- and post-hub data sit near
#: 0.35-0.50x per doubling); this bound only catches a collapse — e.g. a
#: per-round cost blowup like an always-on collective — not timing noise.
SEPS_STEP_TOL = 0.25


def check(payload: dict) -> list[str]:
    """The BENCH_shard.json flatness gate (run via ``--check-only`` in CI).

    Returns a list of human-readable violations (empty = pass):

    - constant-shard ``seconds_per_shard`` within ``CONST_SHARD_TOL`` of the
      D=2 point for every D >= 2 (step cost tracks shard size, not full-V);
    - device-sweep ``bytes_per_device`` falls to at most ``BYTES_STEP_TOL``
      per doubling (per-device memory is the sweep's scaling metric);
    - device-sweep ``sampled_edges_per_s`` keeps at least ``SEPS_STEP_TOL``
      per doubling (cliff-guard only — host wall time is noisy by design);
    - hub replication strictly reduces exchange bytes at D=4/8.
    """
    problems: list[str] = []
    cs = {r["devices"]: r["seconds_per_shard"] for r in payload["constant_shard_scaling"]}
    base = cs.get(2)
    if base is None:
        problems.append("constant_shard_scaling has no D=2 baseline")
    else:
        for dv in sorted(d for d in cs if d >= 2):
            if cs[dv] > CONST_SHARD_TOL * base:
                problems.append(
                    f"const-shard D={dv}: {cs[dv]:.3f}s/shard exceeds "
                    f"{CONST_SHARD_TOL}x the D=2 baseline ({base:.3f}s)"
                )
    seps = {r["devices"]: r["sampled_edges_per_s"] for r in payload["results"]}
    bpd = {r["devices"]: r["bytes_per_device"] for r in payload["results"]}
    chain = sorted(d for d in seps if d >= 2)
    for lo, hi in zip(chain, chain[1:]):
        if bpd[hi] > BYTES_STEP_TOL * bpd[lo]:
            problems.append(
                f"device sweep D={lo}->{hi}: bytes_per_device fell only "
                f"{bpd[lo]} -> {bpd[hi]} (> {BYTES_STEP_TOL}x retained per "
                f"doubling — shards are not shrinking with the mesh)"
            )
        if seps[hi] < SEPS_STEP_TOL * seps[lo]:
            problems.append(
                f"device sweep D={lo}->{hi}: sampled_edges/s fell "
                f"{seps[lo]:.3e} -> {seps[hi]:.3e} "
                f"(> {1 - SEPS_STEP_TOL:.0%} drop per doubling)"
            )
    for h in payload.get("hub_replication", ()):
        if h["exchange_bytes_hubs"] >= h["exchange_bytes_nohubs"]:
            problems.append(
                f"hub ablation D={h['devices']}: replication did not reduce "
                f"exchange bytes ({h['exchange_bytes_hubs']} >= "
                f"{h['exchange_bytes_nohubs']})"
            )
    return problems


def main() -> None:
    if "--check-only" in sys.argv:
        payload = json.loads(OUT_PATH.read_text())
        problems = check(payload)
        for p in problems:
            print(f"FAIL: {p}")
        if problems:
            sys.exit(1)
        print(f"scaling gate OK ({OUT_PATH.name})")
        return
    for r in run():
        print(r)
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
