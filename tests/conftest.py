"""Shared test helpers.

Multi-device suites (``test_multidevice.py``, ``test_shard.py``) run each
case in a subprocess so the main test process keeps its single-device view
(the dry-run isolation rule): the child sets
``--xla_force_host_platform_device_count=8`` before importing jax, asserts
inside, and prints one JSON line the parent parses.
"""
import json
import subprocess
import sys

MULTIDEVICE_HEADER = """
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
"""


def run_multidevice_child(code: str, timeout: int = 420) -> dict:
    """Run ``code`` in a fresh interpreter; return its last stdout line as JSON."""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])
