"""distributed subpackage."""
