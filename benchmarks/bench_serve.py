"""Serving benchmark: fused multi-request batching → BENCH_serve.json.

Measures what the batched multi-instance sampling service (``repro.serve``)
buys over one-launch-per-request serving: 64 concurrent requests are
submitted and drained through (a) fused padding-bucket cohorts and (b) the
bit-identical ``ServiceConfig(fuse=False)`` baseline, across three
request-arrival mixes on the pl50k benchmark graph (reference backend —
the cross-host number; the kernel path only changes what runs inside each
launch, not how many launches there are):

- ``uniform``        — one algorithm, one walk length, one request size;
- ``skewed_lengths`` — same algorithm, power-law-skewed walk lengths
  (depth buckets fragment the cohorts; the realistic arrival case);
- ``mixed_specs``    — node2vec (1 in 4) / deepwalk / weighted mix with
  mixed lengths (cohorts also split per lowered transition program).

Headline: fused-vs-sequential speedup per mix, plus requests/s and
walker-steps/s throughput.  Acceptance floor (ISSUE 4): >= 1.5x on the
mixed-spec mix.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--iters 3]
(also exposed as ``run()`` rows through benchmarks/run.py)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import BENCH_GRAPHS, row  # noqa: E402

from repro.core import algorithms as alg  # noqa: E402
from repro.serve import SamplingService, ServiceConfig  # noqa: E402

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

GRAPH = "pl50k"
N_REQUESTS = 64


def _request_mixes(g, rng):
    """64-request arrival mixes; every request carries an explicit key so the
    fused and sequential services serve literally identical work."""
    n2v = alg.node2vec()  # ONE spec instance: its requests may fuse
    mixes = {}

    # serving-scale requests: a user asks for a handful of walks.  This is
    # the regime batching is FOR — each standalone launch is fixed-overhead
    # dominated, so cohorts amortize it across requests.
    uniform = []
    for i in range(N_REQUESTS):
        uniform.append((alg.deepwalk(), rng.integers(0, g.num_vertices, 16), 16))
    mixes["uniform"] = uniform

    skewed = []
    depths = rng.choice([4, 8, 16, 32, 64], size=N_REQUESTS, p=[0.35, 0.3, 0.2, 0.1, 0.05])
    for i in range(N_REQUESTS):
        skewed.append((alg.deepwalk(), rng.integers(0, g.num_vertices, 16), int(depths[i])))
    mixes["skewed_lengths"] = skewed

    mixed = []
    specs = [alg.deepwalk(), n2v, alg.weighted_random_walk(), alg.deepwalk()]
    for i in range(N_REQUESTS):
        spec = specs[i % len(specs)]
        n = int(rng.integers(9, 17))  # one width bucket, varying fill
        depth = int(rng.choice([8, 16]))
        mixed.append((spec, rng.integers(0, g.num_vertices, n), depth))
    mixes["mixed_specs"] = mixed
    return mixes


def _serve_once(svc, requests, keys):
    for (spec, seeds, depth), key in zip(requests, keys):
        svc.submit(seeds, depth=depth, spec=spec, key=key)
    results = svc.drain()
    assert len(results) == len(requests)
    return results


def _bench_mode(g, requests, keys, fuse, iters):
    """Median submit+drain wall seconds in steady state (post-compile)."""
    mk = lambda: SamplingService(  # noqa: E731
        g, backend="reference", config=ServiceConfig(fuse=fuse)
    )
    svc = mk()
    _serve_once(svc, requests, keys)  # warmup: compile every cohort trace
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _serve_once(svc, requests, keys)
        times.append(time.perf_counter() - t0)
    times.sort()
    stats = svc.stats
    return times[len(times) // 2], stats


def run(iters: int = 3):
    g = BENCH_GRAPHS[GRAPH]()
    rng = np.random.default_rng(17)
    mixes = _request_mixes(g, rng)
    base_key = jax.random.PRNGKey(9)
    results = []
    for mix_name, requests in mixes.items():
        keys = [jax.random.fold_in(base_key, i) for i in range(len(requests))]
        walker_steps = sum(len(s) * d for _, s, d in requests)
        fused_s, fstats = _bench_mode(g, requests, keys, fuse=True, iters=iters)
        seq_s, _ = _bench_mode(g, requests, keys, fuse=False, iters=iters)
        launches_per_drain = fstats.launches // (iters + 1)
        entry = {
            "graph": GRAPH,
            "mix": mix_name,
            "n_requests": len(requests),
            "walker_steps": walker_steps,
            "fused_seconds": fused_s,
            "sequential_seconds": seq_s,
            "speedup": seq_s / fused_s,
            "fused_launches_per_drain": launches_per_drain,
            "fused_requests_per_s": len(requests) / fused_s,
            "fused_walker_steps_per_s": walker_steps / fused_s,
            "sequential_walker_steps_per_s": walker_steps / seq_s,
        }
        results.append(entry)
        yield row(
            f"serve_{mix_name}_fused", fused_s * 1e6,
            f"requests={len(requests)};launches={launches_per_drain};"
            f"speedup={entry['speedup']:.2f}x",
        )
        yield row(f"serve_{mix_name}_sequential", seq_s * 1e6,
                  f"requests={len(requests)};launches={len(requests)}")

    OUT_PATH.write_text(json.dumps({
        # shared benchmark-JSON schema (DESIGN.md §9): diffable PR-over-PR
        "bench": "serve",
        "device": jax.default_backend(),
        "backend": "reference",
        "graph": GRAPH,
        "n_requests": N_REQUESTS,
        "results": results,
    }, indent=2))
    yield row("serve_json", 0.0, str(OUT_PATH.name))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(args.iters):
        print(r, flush=True)


if __name__ == "__main__":
    main()
