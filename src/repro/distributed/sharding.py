"""Logical-axis → mesh sharding rules (DESIGN.md §5).

Params carry logical axis names (models/layers.py ParamDef); this module
maps them to PartitionSpecs for a given mesh, with divisibility-aware
fallback (an axis that does not divide the dim is dropped rather than
letting GSPMD pad — e.g. kv_heads=1 never shards over model=16; the KV
cache shards its *sequence* dim instead: flash-decoding-style split-KV).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Version-spanning ``shard_map``: the API moved from
    ``jax.experimental.shard_map`` (replication check kwarg ``check_rep``,
    partial-manual via the complement set ``auto=``) to ``jax.shard_map``
    (kwarg ``check_vma``, partial-manual via ``axis_names=``).  Both checks
    are disabled — the psum-merge patterns in this repo are intentionally
    unreplicated.  ``axis_names`` takes the NEW-API meaning: the mesh axes
    that become manual (None = all of them)."""
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False, **kw,
        )
    from jax.experimental.shard_map import shard_map  # noqa: PLC0415

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False, **kw,
    )


def abstract_mesh(axis_names: Sequence[str], axis_sizes: Sequence[int]):
    """Version-portable ``AbstractMesh`` constructor.

    The AbstractMesh signature drifted across JAX releases — older versions
    take ``shape_tuple`` (name, size) pairs, newer ones keyword
    ``axis_sizes``/``axis_names`` — so spec-logic tests that only need an
    abstract mesh construct it through this shim.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(
            axis_sizes=tuple(axis_sizes), axis_names=tuple(axis_names)
        )
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def fsdp_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def default_rules(mesh: Mesh, tp: bool = True) -> dict:
    """logical axis -> tuple of mesh axes (in preference order).

    ``tp=False`` (tp_mode="dp"): the model axis joins the fsdp group —
    right for small archs where TP is pure collective overhead
    (EXPERIMENTS.md §Perf xlstm iterations)."""
    fsdp = fsdp_axes(mesh)
    if not tp:
        full = fsdp + ("model",)
        return {
            "vocab": (), "embed": full, "heads": (), "kv_heads": (),
            "mlp": (), "experts": (), "rnn": (), "layers": (),
            "batch": full, "seq": (), None: (),
        }
    return {
        "vocab": ("model",),
        "embed": fsdp,  # FSDP: shard weight embed dim across data(+pod)
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "rnn": ("model",),
        "layers": (),  # scan axis never sharded
        "batch": fsdp,
        "seq": ("model",),
        None: (),
    }


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return math.prod(mesh.shape[n] for n in names)


def spec_for(shape: tuple, axes: tuple, mesh: Mesh, rules: Optional[dict] = None) -> P:
    """Build a PartitionSpec for one array, honoring divisibility and the
    at-most-once-per-mesh-axis constraint."""
    rules = rules or default_rules(mesh)
    used: set = set()
    parts = []
    for dim, logical in zip(shape, axes):
        cand = rules.get(logical, ())
        chosen = ()
        # try the full tuple first, then prefixes/suffixes, then single axes
        options = [cand] + [tuple(a for a in cand if a == x) for x in cand]
        for opt in options:
            opt = tuple(a for a in opt if a not in used)
            if opt and dim % _axis_size(mesh, opt) == 0:
                chosen = opt
                break
        used.update(chosen)
        if len(chosen) == 0:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(chosen)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_specs(axes_tree, shapes_tree, mesh: Mesh, rules: Optional[dict] = None):
    """PartitionSpec pytree from (logical axes, shapes) pytrees."""
    return jax.tree_util.tree_map(
        lambda ax, sh: spec_for(tuple(sh.shape), ax, mesh, rules),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(specs_tree, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs_tree)


def batch_spec(mesh: Mesh, batch: Optional[int] = None) -> P:
    axes = fsdp_axes(mesh)
    if batch is not None and batch % _axis_size(mesh, axes) != 0:
        return P()
    return P(axes)


def div_spec(mesh: Mesh, shape: tuple, *parts) -> P:
    """PartitionSpec with non-divisible axes dropped."""
    out = []
    for dim, p in zip(shape, parts):
        if p is None:
            out.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        if dim % _axis_size(mesh, axes) == 0:
            out.append(p)
        else:
            out.append(None)
    return P(*out)


def cache_spec(shape: tuple, kind: str, mesh: Mesh) -> P:
    """Sharding for decode caches.

    Attention KV (B, S, KVH, Dh): batch→fsdp when divisible; kv_heads→model
    when divisible, else seq→model (split-KV decode); with batch=1 the seq
    dim absorbs the fsdp axes too (sequence parallelism for long_500k).
    Recurrent states (B, ...): batch→fsdp; width dims→model when divisible.
    """
    fsdp = fsdp_axes(mesh)
    used: set = set()
    if kind == "kv" and len(shape) == 4:
        b, s, kvh, hd = shape
        parts: list = [None, None, None, None]
        if b % _axis_size(mesh, fsdp) == 0:
            parts[0] = fsdp if len(fsdp) > 1 else fsdp[0]
            used.update(fsdp)
        if kvh % mesh.shape["model"] == 0:
            parts[2] = "model"
            used.add("model")
        seq_axes = tuple(a for a in (*fsdp, "model") if a not in used)
        if seq_axes and s % _axis_size(mesh, seq_axes) == 0:
            parts[1] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
        return P(*parts)
    # recurrent / generic state: (B, ...) — batch then try model on the last dim
    parts = [None] * len(shape)
    if shape and shape[0] % _axis_size(mesh, fsdp) == 0:
        parts[0] = fsdp if len(fsdp) > 1 else fsdp[0]
    if len(shape) > 1 and shape[-1] % mesh.shape["model"] == 0:
        parts[-1] = "model"
    return P(*parts)
