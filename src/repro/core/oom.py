"""Out-of-memory sampling: workload-aware partition scheduling (paper §V).

The graph lives on the host in contiguous vertex-range partitions; only a
bounded number of partitions is resident in device memory at a time.  The
scheduler:

  1. counts *active* frontier vertices per partition (paper Fig. 8 step 1),
  2. transfers the partitions with the most workload first (step 2) through a
     double-buffered ``TransferEngine`` (the cudaMemcpyAsync analogue),
  3. samples a resident partition until its frontier queue drains, inserting
     successors into the owning partition's queue (cross-partition comm),
  4. repeats until no partition has active vertices (step 3).

Batched multi-instance sampling (§V-C) merges entries of *all* instances into
one queue per partition (metadata: InstanceID, CurrDepth); disabling it
processes instances one at a time — the paper's Fig. 13 baseline.

Thread-block workload balancing (§V-B) becomes proportional chunk scheduling
across co-resident partitions; per-"kernel" processed-entry counts are
recorded so benchmarks can report the paper's Fig. 14 imbalance metric.

This is a host-driven loop by necessity (the paper's is too — the CPU decides
which partition to ship).  Device compute is jit-compiled per partition with
fixed-size padded entry chunks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import SamplingSpec
from repro.core import select as sel
from repro.core.engine import _edge_ctx
from repro.graph.csr import CSRGraph
from repro.graph.partition import RangePartition, partition_of


@dataclasses.dataclass
class OOMStats:
    """Counters mirrored from the paper's out-of-memory evaluation."""

    partition_transfers: int = 0
    bytes_transferred: int = 0
    kernel_launches: int = 0
    entries_per_kernel: Optional[List[int]] = None
    sampled_edges: int = 0

    def __post_init__(self):
        if self.entries_per_kernel is None:
            self.entries_per_kernel = []

    def kernel_time_std(self) -> float:
        """Std of per-kernel workload (entry counts) — Fig. 14 proxy."""
        if not self.entries_per_kernel:
            return 0.0
        return float(np.std(np.asarray(self.entries_per_kernel, dtype=np.float64)))


class TransferEngine:
    """Double-buffered host->device partition transfers with an LRU of
    ``capacity`` resident partitions (the 'GPU memory holds k partitions'
    constraint in the paper's Fig. 8 walkthrough)."""

    def __init__(self, partitions: List[RangePartition], total_vertices: int, capacity: int):
        self.partitions = partitions
        self.total_vertices = total_vertices
        self.capacity = capacity
        self._resident: dict[int, CSRGraph] = {}
        self._lru: list[int] = []
        self.stats_transfers = 0
        self.stats_bytes = 0

    def fetch(self, pid: int) -> CSRGraph:
        if pid in self._resident:
            self._lru.remove(pid)
            self._lru.append(pid)
            return self._resident[pid]
        if len(self._resident) >= self.capacity:
            evict = self._lru.pop(0)
            del self._resident[evict]
        part = self.partitions[pid]
        dev = part.to_device_csr(self.total_vertices)  # the DMA
        self.stats_transfers += 1
        self.stats_bytes += part.nbytes()
        self._resident[pid] = dev
        self._lru.append(pid)
        return dev


@functools.partial(jax.jit, static_argnames=("max_degree", "spec"))
def _walk_step_kernel(graph: CSRGraph, cur, prev, key, *, max_degree: int, spec: SamplingSpec):
    """One walk step for a padded chunk of queue entries (cur < 0 = padding)."""
    ctx, mask = _edge_ctx(graph, cur, prev, jnp.zeros((), jnp.int32), max_degree, spec.needs_prev_neighbors)
    biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
    idx = sel.select_with_replacement(key, biases, mask, 1)[..., 0]
    u = jnp.take_along_axis(ctx.u, idx[..., None], axis=-1)[..., 0]
    alive = (cur >= 0) & jnp.any(mask, axis=-1)
    u = jnp.where(alive, u, -1)
    return spec.update(jax.random.fold_in(key, 7), ctx, u)


@functools.partial(jax.jit, static_argnames=("max_degree", "spec", "method"))
def _neighbor_step_kernel(graph: CSRGraph, cur, key, *, max_degree: int, spec: SamplingSpec, method: str):
    """NeighborSize successors per entry, without replacement."""
    prev = jnp.full_like(cur, -1)
    ctx, mask = _edge_ctx(graph, cur, prev, jnp.zeros((), jnp.int32), max_degree, False)
    biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
    res = sel.select_without_replacement(key, biases, mask, spec.neighbor_size, method=method)
    u = jnp.where(res.valid, jnp.take_along_axis(ctx.u, jnp.maximum(res.indices, 0), axis=-1), -1)
    return jnp.where((cur >= 0)[..., None], u, -1)


class _Queue:
    """Per-partition frontier queue: (vertex, instance, depth, prev) arrays."""

    def __init__(self):
        self.vertex: list[int] = []
        self.instance: list[int] = []
        self.depth: list[int] = []
        self.prev: list[int] = []

    def push(self, v, inst, d, prev):
        self.vertex.append(int(v))
        self.instance.append(int(inst))
        self.depth.append(int(d))
        self.prev.append(int(prev))

    def push_many(self, v, inst, d, prev):
        self.vertex.extend(int(x) for x in v)
        self.instance.extend(int(x) for x in inst)
        self.depth.extend(int(x) for x in d)
        self.prev.extend(int(x) for x in prev)

    def pop_chunk(self, n: int):
        n = min(n, len(self.vertex))
        out = (
            np.array(self.vertex[:n], np.int32),
            np.array(self.instance[:n], np.int32),
            np.array(self.depth[:n], np.int32),
            np.array(self.prev[:n], np.int32),
        )
        del self.vertex[:n], self.instance[:n], self.depth[:n], self.prev[:n]
        return out

    def __len__(self):
        return len(self.vertex)


def oom_random_walk(
    partitions: List[RangePartition],
    total_vertices: int,
    seeds: np.ndarray,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    memory_capacity: int = 2,
    num_streams: int = 2,
    chunk: int = 1024,
    batched: bool = True,
    workload_aware: bool = True,
    balance: bool = True,
) -> tuple[np.ndarray, OOMStats]:
    """Out-of-memory random walk over host-resident partitions.

    Returns (walks (I, depth+1), stats).  Flags map to the paper's ablations:
    ``batched`` = §V-C, ``workload_aware`` = §V-B scheduling, ``balance`` =
    thread-block workload balancing (proportional chunk sizing).
    """
    num_parts = len(partitions)
    num_inst = len(seeds)
    walks = np.full((num_inst, depth + 1), -1, np.int32)
    walks[:, 0] = seeds
    queues = [_Queue() for _ in range(num_parts)]
    pids = partition_of(seeds, total_vertices, num_parts)
    for i, (s, p) in enumerate(zip(seeds, pids)):
        queues[p].push(s, i, 0, -1)

    engine = TransferEngine(partitions, total_vertices, memory_capacity)
    stats = OOMStats()
    kcounter = 0

    def drain(pid: int, graph: CSRGraph, budget: int) -> int:
        """Process up to ``budget`` entries of queue[pid]; return processed."""
        nonlocal kcounter
        q = queues[pid]
        processed = 0
        while len(q) and processed < budget:
            take = min(chunk, budget - processed, len(q))
            if not batched:
                # paper Fig.13 baseline: one instance at a time
                inst0 = q.instance[0]
                take = 1
                while take < min(chunk, len(q)) and q.instance[take] == inst0:
                    take += 1
            v, inst, d, prev = q.pop_chunk(take)
            pad = chunk - len(v)
            vp = np.pad(v, (0, pad), constant_values=-1)
            pp = np.pad(prev, (0, pad), constant_values=-1)
            kcounter += 1
            kkey = jax.random.fold_in(key, kcounter)
            nxt = np.asarray(
                _walk_step_kernel(graph, jnp.asarray(vp), jnp.asarray(pp), kkey,
                                  max_degree=max_degree, spec=spec)
            )[: len(v)]
            stats.kernel_launches += 1
            stats.entries_per_kernel.append(len(v))
            alive = nxt >= 0
            walks[inst[alive], d[alive] + 1] = nxt[alive]
            stats.sampled_edges += int(alive.sum())
            cont = alive & (d + 1 < depth)
            if cont.any():
                npid = partition_of(nxt[cont], total_vertices, num_parts)
                for tp in np.unique(npid):
                    m = npid == tp
                    queues[tp].push_many(nxt[cont][m], inst[cont][m], d[cont][m] + 1, v[cont][m])
            processed += len(v)
        return processed

    while True:
        counts = np.array([len(q) for q in queues])
        if counts.sum() == 0:
            break
        if workload_aware:
            order = np.argsort(-counts)
        else:
            order = np.arange(num_parts)  # fixed round-robin baseline
        active = [int(p) for p in order if counts[p] > 0][:num_streams]
        total_active = counts[active].sum()
        for pid in active:
            graph = engine.fetch(pid)
            if balance:
                budget = max(chunk, int(np.ceil(counts[pid] / max(total_active, 1) * num_streams * chunk)))
            else:
                budget = chunk * num_streams
            # paper: sample the partition until its queue has no active vertices
            while len(queues[pid]):
                drain(pid, graph, budget)
                if not workload_aware:
                    break  # baseline releases the partition after one pass

    stats.partition_transfers = engine.stats_transfers
    stats.bytes_transferred = engine.stats_bytes
    return walks, stats
