"""Adaptive selection runtime (core.methods, DESIGN.md §13).

Four layers:

- Alias-table construction properties (deterministic random trials, plus
  hypothesis versions when the plugin is installed): the (prob, alias)
  pair reconstructs the normalized bias exactly, including degenerate rows
  (zero bias, single edge, all-equal).
- The cost model: per-cohort picks and overrides.
- Draw-level and walk-level cross-backend bit-parity for the alias and
  rejection methods (forced via ``SamplingSpec.selection_method``),
  in-memory and out-of-memory; the sharded mesh parity runs in a
  subprocess (same harness as ``test_shard.py``).
- The explicit reference-fallback flag on ``select_without_replacement``
  and the serving ``prewarm()`` hook.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import MULTIDEVICE_HEADER as HEADER, run_multidevice_child as run_child
from repro.core import algorithms as alg
from repro.core import backend as bk
from repro.core import methods as mt
from repro.core import select as sel
from repro.core.engine import flat_method_plan, random_walk
from repro.core.oom import oom_random_walk
from repro.core.transition import lower
from repro.graph import powerlaw_graph
from repro.graph.partition import partition_by_vertex_range
from repro.kernels import ref
from repro.kernels.alias_select import alias_step_pallas
from repro.kernels.walk_step import pad_csr_for_kernel, reject_step_pallas
from repro.serve.service import SamplingService

KEY = jax.random.PRNGKey(0)


def _csr_from_rows(rows):
    """rows: list of per-row bias lists -> (indptr, bias) numpy."""
    indptr = np.zeros(len(rows) + 1, np.int64)
    for i, r in enumerate(rows):
        indptr[i + 1] = indptr[i] + len(r)
    bias = np.concatenate([np.asarray(r, np.float64) for r in rows]) if indptr[-1] \
        else np.zeros((0,), np.float64)
    return indptr, bias


def _reconstruct_pmf(indptr, bias, prob, alias):
    """The distribution an alias draw realizes, per edge (host float64)."""
    pmf = np.zeros_like(bias, dtype=np.float64)
    for v in range(len(indptr) - 1):
        s, e = int(indptr[v]), int(indptr[v + 1])
        d = e - s
        if d == 0:
            continue
        for j in range(d):
            pj = float(prob[s + j])
            pmf[s + j] += pj
            a = int(alias[s + j])
            if a >= 0:
                pmf[s + a] += 1.0 - pj
        pmf[s:e] /= d
    return pmf


def _check_rows(rows):
    indptr, bias = _csr_from_rows(rows)
    prob, alias = sel.build_alias(indptr, bias)
    assert prob.shape == bias.shape and alias.shape == bias.shape
    pmf = _reconstruct_pmf(indptr, bias, prob, alias)
    for v in range(len(rows)):
        s, e = int(indptr[v]), int(indptr[v + 1])
        tot = bias[s:e].sum()
        if e == s:
            continue
        if tot <= 0:
            # dead row: zero acceptance, every alias a -1 sentinel
            np.testing.assert_array_equal(prob[s:e], 0.0)
            np.testing.assert_array_equal(alias[s:e], -1)
        else:
            np.testing.assert_allclose(
                pmf[s:e], bias[s:e] / tot, rtol=1e-5, atol=1e-7
            )
            # redirects stay row-local
            assert alias[s:e].min() >= 0 and alias[s:e].max() < e - s


class TestAliasBuild:
    def test_reconstructs_normalized_bias_random_trials(self):
        rng = np.random.default_rng(0)
        for trial in range(25):
            rows = [
                list(rng.gamma(0.5, 2.0, size=rng.integers(0, 14)))
                for _ in range(rng.integers(1, 10))
            ]
            _check_rows(rows)

    def test_degenerate_rows(self):
        _check_rows([
            [0.0, 0.0, 0.0],     # zero-bias row -> dead
            [3.5],               # single edge -> prob 1, self alias
            [2.0, 2.0, 2.0, 2.0],  # all-equal -> prob 1 everywhere
            [],                  # empty row
            [0.0, 5.0, 0.0],     # zero-bias edges inside a live row
            [1e-12, 1e12],       # extreme skew
        ])
        indptr, bias = _csr_from_rows([[2.0, 2.0], [0.0, 7.0, 0.0]])
        prob, alias = sel.build_alias(indptr, bias)
        np.testing.assert_allclose(prob[:2], 1.0)  # all-equal: never redirect
        pmf = _reconstruct_pmf(indptr, bias, prob, alias)
        np.testing.assert_allclose(pmf[2:], [0.0, 1.0, 0.0], atol=1e-7)

    def test_reconstruction_hypothesis(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        weight = st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False)

        @settings(max_examples=60, deadline=None)
        @given(st.lists(st.lists(weight, max_size=12), min_size=1, max_size=8))
        def prop(rows):
            _check_rows(rows)

        prop()

    def test_row_max_hypothesis(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        weight = st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False)

        @settings(max_examples=60, deadline=None)
        @given(st.lists(st.lists(weight, max_size=10), min_size=1, max_size=8))
        def prop(rows):
            indptr, bias = _csr_from_rows(rows)
            rm = sel.build_row_max(indptr, bias)
            expect = [max(r) if r else 0.0 for r in rows]
            np.testing.assert_allclose(rm, expect)

        prop()


class TestCostModel:
    BUCKETS = (4, 16)

    def _stats(self, rows):
        indptr, bias = _csr_from_rows(rows)
        deg = np.diff(indptr)
        return deg, mt.row_stats(indptr, bias, deg)

    def test_uniform_rows_pick_rejection(self):
        deg, stats = self._stats([[1.0] * 3, [2.0] * 2, [5.0] * 8])
        methods = mt.plan_methods(deg, stats, buckets=self.BUCKETS, use_chunked=False)
        assert methods == ("rejection", "rejection")

    def test_skewed_rows_pick_alias(self):
        deg, stats = self._stats([[100.0, 1.0, 1.0], [50.0, 1.0] * 4])
        methods = mt.plan_methods(deg, stats, buckets=self.BUCKETS, use_chunked=False)
        assert methods == ("alias", "alias")

    def test_zero_bias_edge_forces_alias_even_when_uniform(self):
        # rejection would burn budget proposing the dead edge
        deg, stats = self._stats([[1.0, 1.0, 0.0]])
        methods = mt.plan_methods(deg, stats, buckets=self.BUCKETS, use_chunked=False)
        assert methods[0] == "alias"

    def test_empty_cohort_stays_its(self):
        deg, stats = self._stats([[1.0, 1.0]])  # nothing above the first bucket
        methods = mt.plan_methods(deg, stats, buckets=self.BUCKETS, use_chunked=True)
        assert methods == ("rejection", "its", "its")

    def test_override_pins_every_cohort(self):
        deg, stats = self._stats([[1.0] * 3, [9.0, 1.0] * 10])
        for o in ("its", "alias", "rejection"):
            methods = mt.plan_methods(
                deg, stats, buckets=self.BUCKETS, use_chunked=True, override=o
            )
            assert methods == (o,) * 3

    def test_plan_for_graph_caches_tables(self):
        g = powerlaw_graph(300, seed=1, weighted=True)
        mt.clear_plan_cache()
        fn = lower(alg.weighted_random_walk()).bias.fn
        m1, t1 = mt.plan_for_graph(g, fn, buckets=(128,), use_chunked=True)
        m2, t2 = mt.plan_for_graph(g, fn, buckets=(128,), use_chunked=True)
        assert m1 == m2 and not mt.is_trivial(m1)
        for a, b in zip(t1, t2):
            assert a is b  # cache hit: the very same arrays, no rebuild

    def test_deepwalk_auto_plan_is_rejection(self):
        g = powerlaw_graph(300, seed=1)
        methods, tables = flat_method_plan(g, lower(alg.deepwalk()), int(g.max_degree()))
        assert set(methods) <= {"rejection", "its"} and "rejection" in methods
        assert tables.row_max is not None and tables.prob is None

    def test_spec_override_reaches_plan(self):
        g = powerlaw_graph(300, seed=1)
        pinned = dataclasses.replace(alg.deepwalk(), selection_method="alias")
        methods, tables = flat_method_plan(g, lower(pinned), int(g.max_degree()))
        assert set(methods) == {"alias"} and tables.alias is not None


class TestDrawParity:
    """Kernel vs pure-jnp flat draw, same tables, same counted uniforms."""

    SEG = 128

    def _graph_tables(self):
        g = powerlaw_graph(600, seed=2, weighted=True)
        indptr = np.asarray(g.indptr)
        bias = np.maximum(np.asarray(g.weights, np.float64), 0.0)
        prob, alias = sel.build_alias(indptr, bias)
        rmax = sel.build_row_max(indptr, bias)
        return g, jnp.asarray(prob), jnp.asarray(alias), jnp.asarray(rmax)

    def test_alias_kernel_bit_identical(self):
        g, prob, alias, _ = self._graph_tables()
        deg_all = np.diff(np.asarray(g.indptr))
        rows = np.nonzero(deg_all > 0)[0][:256].astype(np.int32)
        starts = jnp.asarray(np.asarray(g.indptr)[rows])
        degs = jnp.asarray(deg_all[rows].astype(np.int32))
        rand = jax.random.uniform(KEY, rows.shape, dtype=jnp.float32)
        flat = sel.alias_draw_flat(
            starts, degs, prob, alias, g.indices, rand, cap=self.SEG
        )
        inds_p, _ = pad_csr_for_kernel(g.indices, g.weights, self.SEG)
        a_pad, p_pad = pad_csr_for_kernel(alias, prob, self.SEG)
        kern = alias_step_pallas(
            starts, degs, inds_p, p_pad, a_pad, rand, max_seg=self.SEG
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(kern))
        oracle = ref.alias_step_block_ref(
            starts, degs, inds_p, p_pad, a_pad, rand, seg=self.SEG
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(oracle))

    def test_rejection_kernel_bit_identical(self):
        g, _, _, rmax = self._graph_tables()
        deg_all = np.diff(np.asarray(g.indptr))
        rows = np.nonzero(deg_all > 0)[0][:256].astype(np.int32)
        starts = jnp.asarray(np.asarray(g.indptr)[rows])
        degs = jnp.asarray(deg_all[rows].astype(np.int32))
        rmv = rmax[jnp.asarray(rows)]
        rej = sel.rejection_randoms(KEY, rows.shape)
        flat = sel.rejection_draw_flat(
            starts, degs, g.weights, rmv, g.indices, rej, cap=self.SEG
        )
        inds_p, bias_p = pad_csr_for_kernel(g.indices, g.weights, self.SEG)
        kern = reject_step_pallas(
            starts, degs, inds_p, bias_p, rmv, rej, max_seg=self.SEG
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(kern))
        oracle = ref.reject_step_block_ref(
            starts, degs, inds_p, bias_p, rmv, rej, seg=self.SEG
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(oracle))
        # draws are a pure function of the counted budget: replay == replay
        again = sel.rejection_draw_flat(
            starts, degs, g.weights, rmv, g.indices, rej, cap=self.SEG
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))

    def test_dead_rows_stay_dead(self):
        indptr = jnp.asarray(np.array([0, 0, 2], np.int32))
        bias = jnp.asarray(np.array([0.0, 0.0], np.float32))
        prob, alias = sel.build_alias(np.array([0, 0, 2]), np.zeros(2))
        starts = indptr[:2]
        degs = jnp.asarray(np.array([0, 2], np.int32))
        indices = jnp.asarray(np.array([5, 6], np.int32))
        rand = jnp.asarray(np.array([0.3, 0.9], np.float32))
        out = sel.alias_draw_flat(
            starts, degs, jnp.asarray(prob), jnp.asarray(alias), indices, rand
        )
        np.testing.assert_array_equal(np.asarray(out), [-1, -1])
        rej = sel.rejection_randoms(KEY, (2,))
        out = sel.rejection_draw_flat(
            starts, degs, bias, jnp.zeros(2), indices, rej
        )
        np.testing.assert_array_equal(np.asarray(out), [-1, -1])


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("method", ["alias", "rejection"])
class TestWalkParity:
    def test_forced_method_bitwise_inmem(self, method, backend):
        g = powerlaw_graph(500, seed=4, weighted=True)
        spec = dataclasses.replace(
            alg.weighted_random_walk(), selection_method=method
        )
        seeds = jnp.arange(128) % 500
        md = int(g.max_degree())
        res = random_walk(g, seeds, KEY, depth=6, spec=spec, max_degree=md,
                          backend=backend)
        ref = random_walk(g, seeds, KEY, depth=6, spec=spec, max_degree=md,
                          backend="reference")
        assert jnp.array_equal(res.walks, ref.walks)
        # walks end at real neighbors of their predecessors
        walks = np.asarray(res.walks)
        indptr, indices = np.asarray(g.indptr), np.asarray(g.indices)
        for r in range(0, 128, 17):
            for t in range(6):
                v, u = walks[r, t], walks[r, t + 1]
                if v < 0 or u < 0:
                    break
                assert u in indices[indptr[v]:indptr[v + 1]]

    def test_forced_method_bitwise_oom(self, method, backend):
        g = powerlaw_graph(300, seed=6, weighted=True)
        parts = partition_by_vertex_range(g, 3)
        spec = dataclasses.replace(
            alg.weighted_random_walk(), selection_method=method
        )
        seeds = np.arange(48) % 300
        w, _ = oom_random_walk(parts, 300, seeds, KEY, depth=4, spec=spec,
                               max_degree=int(g.max_degree()), backend=backend)
        wr, _ = oom_random_walk(parts, 300, seeds, KEY, depth=4, spec=spec,
                                max_degree=int(g.max_degree()), backend="reference")
        assert np.array_equal(w, wr)
        assert (w[:, 1] >= 0).any()


def test_sharded_forced_methods_bit_identical_to_inmem():
    """Forced alias/rejection under the mesh drain == in-memory engine,
    bit for bit (the §12 parity contract extended to the new methods)."""
    out = run_child(HEADER + """
import dataclasses
from jax.sharding import Mesh
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.graph import powerlaw_graph
from repro.shard.walk import sharded_random_walk

g = powerlaw_graph(300, seed=3, weighted=True)
mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
seeds = jnp.arange(64) % 300
key = jax.random.PRNGKey(11)
md = int(g.max_degree())
ok = {}
for m, be in (("alias", "reference"), ("alias", "pallas"), ("rejection", "reference")):
    spec = dataclasses.replace(alg.weighted_random_walk(), selection_method=m)
    ref = random_walk(g, seeds, key, depth=5, spec=spec, max_degree=md, backend=be)
    res = sharded_random_walk(mesh, g, seeds, key, depth=5, spec=spec,
                              max_degree=md, backend=be)
    ok[m + "_" + be] = bool(jnp.array_equal(ref.walks, res.walks))
print(json.dumps(ok))
""")
    assert all(out.values()), out


def test_select_fallback_flag_is_explicit():
    b = jax.random.uniform(KEY, (8, 32))
    ref = bk.select_without_replacement(KEY, b, None, 2, method="gumbel",
                                        backend="reference")
    pal = bk.select_without_replacement(KEY, b, None, 2, method="gumbel",
                                        backend="pallas")
    assert not ref.fell_back and pal.fell_back
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(pal.indices))
    kern = bk.select_without_replacement(KEY, b, None, 2, method="its_brs",
                                         backend="pallas")
    assert not kern.fell_back


def test_service_prewarm_builds_and_reuses_plan():
    g = powerlaw_graph(400, seed=8, weighted=True)
    svc = SamplingService(g, backend="reference")
    spec = dataclasses.replace(alg.weighted_random_walk(), selection_method="alias")
    mt.clear_plan_cache()
    methods = svc.prewarm(spec)
    assert set(methods) == {"alias"}
    assert svc.stats.plans_prewarmed == 1
    _, t1 = mt.plan_for_graph(
        g, lower(spec).bias.fn, buckets=bk.walk_bucket_plan(int(g.max_degree()))[0],
        use_chunked=bk.walk_bucket_plan(int(g.max_degree()))[1], override="alias"
    )
    rid = svc.submit(np.arange(32) % 400, depth=4, spec=spec)
    out = svc.drain()
    assert out[rid].walks.shape == (32, 5)
    # the drain reused the prewarmed cache entry (same array objects)
    _, t2 = mt.plan_for_graph(
        g, lower(spec).bias.fn, buckets=bk.walk_bucket_plan(int(g.max_degree()))[0],
        use_chunked=bk.walk_bucket_plan(int(g.max_degree()))[1], override="alias"
    )
    assert t1.prob is t2.prob
    # non-flat specs have nothing to prebuild
    assert svc.prewarm(alg.node2vec()) == ()
