"""Multi-device behaviour via subprocesses (host-device override).

The main test process must keep its single-device view (dry-run isolation
rule), so each case boots a small JAX instance with
``--xla_force_host_platform_device_count=N`` and asserts inside.
"""
import pytest

from conftest import MULTIDEVICE_HEADER as HEADER, run_multidevice_child as run_child


@pytest.mark.slow
def test_instance_parallel_walk_multidevice():
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk
g = powerlaw_graph(512, seed=1, weighted=True)
mesh = jax.make_mesh((4,), ("data",))
seeds = jax.random.randint(jax.random.PRNGKey(0), (64,), 0, 512)
res = instance_parallel_walk(mesh, g, seeds, jax.random.PRNGKey(1), depth=8,
                             spec=alg.deepwalk(), max_degree=g.max_degree())
walks = np.asarray(res.walks)
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
bad = 0
for row in walks:
    for a, b in zip(row[:-1], row[1:]):
        if a < 0 or b < 0: break
        if b not in ind[ip[a]:ip[a+1]]: bad += 1
print(json.dumps({"bad": bad, "edges": int(res.sampled_edges), "shape": list(walks.shape)}))
""")
    assert d["bad"] == 0 and d["edges"] > 0 and d["shape"] == [64, 9]


@pytest.mark.slow
def test_graph_sharded_walk_multidevice():
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import graph_sharded_walk
g = powerlaw_graph(512, seed=2, weighted=True)
mesh = jax.make_mesh((4,), ("data",))
seeds = jax.random.randint(jax.random.PRNGKey(0), (32,), 0, 512)
walks = np.asarray(graph_sharded_walk(mesh, g, seeds, jax.random.PRNGKey(1), depth=6,
                                      spec=alg.deepwalk(), max_degree=g.max_degree()))
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
bad = 0
for row in walks:
    for a, b in zip(row[:-1], row[1:]):
        if a < 0 or b < 0: break
        if b not in ind[ip[a]:ip[a+1]]: bad += 1
print(json.dumps({"bad": bad}))
""")
    assert d["bad"] == 0


@pytest.mark.slow
@pytest.mark.xfail(
    reason="pinned-jaxlib XLA abort: sharding.IsManualSubgroup() in "
    "partial-manual shard_map + remat'd scan",
    strict=False,
)
def test_compressed_pod_gradients():
    """int8 error-feedback gradient reduction over a manual pod axis."""
    d = run_child(HEADER + """
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.train.optimizer import OptConfig, opt_init
from repro.train.train_step import make_train_step
import numpy as np
cfg = get_smoke_config("internlm2-1.8b")
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
ocfg = OptConfig(kind="adamw", lr=1e-3, warmup_steps=1)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
step = jnp.zeros((), jnp.int32)
losses = {}
for compressed in (False, True):
    # fresh state per variant: the step donates params/opt_state
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_init(ocfg, params)
    fn, _ = make_train_step(cfg, ocfg, mesh, compressed=compressed)
    p, o, s, m = fn(params, opt_state, step, batch)
    losses[compressed] = float(m["loss"])
rel = abs(losses[True] - losses[False]) / abs(losses[False])
print(json.dumps({"loss_plain": losses[False], "loss_comp": losses[True], "rel": rel}))
""")
    assert d["rel"] < 0.05, d  # int8 compression: same loss, ~same update


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes():
    """Save on a 4x2 mesh, restore onto 2x1 (simulated node loss)."""
    d = run_child(HEADER + """
import tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import largest_mesh_shape
tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(4)}
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
sh1 = {"w": NamedSharding(mesh1, P("data", "model")), "b": NamedSharding(mesh1, P())}
tree1 = jax.tree_util.tree_map(jax.device_put, tree, sh1)
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, keep=2, fingerprint="elastic")
mgr.save(3, tree1)
# node loss: only 2 devices remain
shape = largest_mesh_shape(2, 2)
mesh2 = jax.sharding.Mesh(np.array(jax.devices()[:2]).reshape(shape), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("data", "model")), "b": NamedSharding(mesh2, P())}
restored, man = mgr.restore(tree, shardings=sh2)
ok = bool(jnp.allclose(restored["w"], tree["w"])) and man["step"] == 3
nshards = len(restored["w"].sharding.device_set)
print(json.dumps({"ok": ok, "shards": nshards}))
""")
    assert d["ok"] and d["shards"] == 2
