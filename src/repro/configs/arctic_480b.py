"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

Assigned: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2.  Dense-MoE hybrid: a parallel dense FFN residual rides
alongside the routed experts every layer.  Adafactor (factored second
moment) so 480B of state fits the pod (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=("global",),
    num_experts=128,
    num_experts_per_tok=2,
    moe_dense_ff=4864,
    capacity_factor=1.25,
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    optimizer="adafactor",
    # §Perf arctic it.1: mb=4 cuts expert-weight gather+grad traffic 1.7x
    # (also required: per-mb batch must divide the 32-way multipod fsdp)
    microbatches=4,
    reduce_dtype="bf16",
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    pattern=("global",),
    num_experts=8,
    num_experts_per_tok=2,
    moe_dense_ff=96,
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
