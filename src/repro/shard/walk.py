"""Owner-routed sharded random walk over a device mesh (paper §V-D, scaled).

Each device of the mesh holds ONE contiguous vertex-range partition as a
compact local-id CSR (HBM ∝ 1/D, ``graph.partition.DevicePartition``) and a
device-resident frontier queue of the walkers currently AT its vertices
(``shard.exchange.ShardQueue``).  A drain round:

1. pops the local queue (every popped walker's vertex is locally owned, so
   its full neighbor row is resident),
2. takes one walk step through the SAME degree-bucketed selection dispatch
   the single-device engines use (``core.backend``; flat- and window-bias
   transition programs, both backends),
3. routes survivors to the shard owning their new vertex: per-destination
   cumsum compaction into fixed ``(D, slots)`` buffers, ONE tiled
   ``all_to_all``, per-destination overflow *deferred* to the next round
   (never dropped),
4. pushes received walkers into the local queue; a ``psum`` of live counts
   decides termination.

The whole drain is one ``lax.scan`` inside one ``shard_map`` inside one
``jit`` per (shard shape, spec, backend) — meshes of the same shape reuse
the trace; a host loop re-invokes the compiled block only while walkers
remain (deferred-overflow slack).

**Bit-identical parity.**  ``sharded_random_walk`` reproduces single-device
``engine.random_walk`` exactly, bit for bit, on both backends, because every
source of divergence is pinned (DESIGN.md §12):

- *RNG*: the engine draws each step's uniforms as position-indexed ``(W,)``
  vectors under ``fold_in(key, depth)`` chains.  The sharded drain derives
  the SAME counted stream per entry — keyed by the walker's own (depth,
  instance), not by its slot on whatever device it landed on — via
  ``draw(key_of(depth))[instance]``.
- *Selection arithmetic*: the pick kernels cumsum block-aligned CSR windows
  whose float association is fixed by within-window position, so partitions
  are materialized with ``edge_align = max(buckets)`` lead padding —
  every row keeps its global ``start % seg`` offset and the partition-local
  cumsum reproduces the full-graph bits.
- *Flat biases*: evaluated ONCE on the full graph at partition time and
  sliced per shard (a neighbor-degree bias needs non-resident degrees, which
  a shard cannot see), so per-edge bias bits match by construction.
- *Prev-dependent window biases* (node2vec): the previous vertex's neighbor
  row is CARRIED with the walker through the exchange (gathered at the
  source shard, which owns it), so ``is_prev_neighbor`` is exact without
  any replicated adjacency.

Programs outside the supported envelope — opaque biases, window biases that
read non-resident neighbor degrees (``needs_deg_u``), MH-accept / opaque
epilogues — fall back to :func:`replicated_psum_walk`: edges sharded 1/D,
walker state replicated, owner-computed successors ``psum``-merged (the
pre-exchange design; correct, collective-heavy, not parity-exact).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.api import EdgeCtx, SamplingSpec
from repro.core import backend as bk
from repro.core import methods as mt
from repro.core import select as sel
from repro.core import transition as tp
from repro.core.engine import WalkResult, _degree, _edge_ctx, flat_method_plan
from repro.distributed.sharding import shard_map_compat
from repro.graph.csr import CSRGraph
from repro.graph.partition import (
    DevicePartition,
    PartitionMap,
    partition_by_vertex_range,
    pid_of_device,
)
from repro.shard import exchange as ex

#: safety valve on the host drain loop (each block makes guaranteed progress
#: as long as exchange_slots >= 1, so this is never hit by a sane config)
_MAX_BLOCKS = 4096


def _per_entry(base_key, d, inst, valid, draw):
    """Per-entry counted RNG: ``draw(fold_in(base_key, d_e))[inst_e]``.

    ``draw(key) -> (W,)`` must reproduce one of the engine's per-step
    position-indexed vectors; indexing it at the walker's instance id makes
    the draw placement-independent.  The common case — every live entry in
    the batch at the same depth (no deferral backlog) — computes ONE ``(W,)``
    vector and gathers; mixed-depth batches pay a vmapped per-entry draw.
    """
    i = jnp.maximum(inst, 0)
    d0 = d[0]
    same = jnp.all(~valid | (d == d0))

    def cheap(_):
        return draw(jax.random.fold_in(base_key, d0))[i]

    def general(_):
        return jax.vmap(lambda dd, j: draw(jax.random.fold_in(base_key, dd))[j])(d, i)

    return jax.lax.cond(same, cheap, general, None)


def _carried_window_bias(graph, program, v, prev, d, curq, prow):
    """The window-bias hook closed over carried walker state.

    Mirrors ``engine._window_bias_fn`` except that prev-neighbor membership
    is an exact compare against the CARRIED ``(B, prow_w)`` neighbor row of
    ``prev`` (``-2``-padded, gathered at the source shard) instead of a
    binary search over a resident CSR — identical booleans, no replicated
    adjacency.  ``needs_deg_u`` hooks are rejected upstream (a shard cannot
    see non-resident degrees), so ``deg_u`` reads as zeros exactly like the
    engine's ``needs_deg_u=False`` path.
    """
    wb = program.bias
    deg_v = _degree(graph, curq)

    def bias_of(u, w, mask):
        ipn = None
        if wb.needs_prev_neighbors:
            ipn = (
                jnp.any(u[..., :, None] == prow[..., None, :], axis=-1)
                & mask
                & (prev >= 0)[..., None]
                & (u >= 0)
            )
        ctx = EdgeCtx(
            v=v, u=u, weight=w, deg_v=deg_v,
            deg_u=jnp.zeros(u.shape, jnp.int32), prev=prev,
            is_prev_neighbor=ipn, depth=d[..., None],
        )
        return wb.fn(ctx)

    return bias_of


# ---------------------------------------------------------------------------
# The compiled drain block (one jit per config; cached)
# ---------------------------------------------------------------------------

_DRAIN_CACHE: dict = {}
#: bound on cached drain traces — like every jit-static-spec entry point in
#: this repo (engine.random_walk, oom._drain), a FRESHLY CONSTRUCTED spec is
#: a new trace key (its hooks are new closures), so callers should reuse
#: spec objects across calls; the bound turns a caller that doesn't into
#: steady-state recompiles instead of an unbounded cache leak
_DRAIN_CACHE_MAX = 64


def _drain_block(
    mesh: Mesh, axis: str, *, spec: SamplingSpec, be: str, num_devices: int,
    num_inst: int, depth: int, cap: int, slots: int, prow_w: int,
    buckets: tuple, use_chunked: bool, rounds: int, range_size: int,
    methods: tuple = (),
):
    """Build (or fetch) the jitted shard_map drain for one static config."""
    cfg = (mesh, axis, spec, be, num_devices, num_inst, depth, cap, slots,
           prow_w, buckets, use_chunked, rounds, range_size, methods)
    if cfg in _DRAIN_CACHE:
        return _DRAIN_CACHE[cfg]
    while len(_DRAIN_CACHE) >= _DRAIN_CACHE_MAX:
        _DRAIN_CACHE.pop(next(iter(_DRAIN_CACHE)))

    program = tp.lower(spec)
    mode = program.mode
    needs_prev = prow_w > 0
    nfields = 5 if needs_prev else 4
    num_dest = num_devices

    use_alias = any(m == "alias" for m in methods)
    use_rej = any(m == "rejection" for m in methods)

    def body(indptr, iloc, iglob, wts, bias, vlo, prob, alias, rowmax,
             qfields, qcount, qdropped, dfields, dcount,
             walks, key, seeds, limits):
        indptr, iloc, iglob, wts, bias, vlo0 = (
            indptr[0], iloc[0], iglob[0], wts[0], bias[0], vlo[0]
        )
        # partition-local slices of the full-graph method tables; None'd out
        # when the plan never reads them, exactly like the engine's pytree
        tbl = mt.MethodTables(
            prob=prob[0] if use_alias else None,
            alias=alias[0] if use_alias else None,
            row_max=rowmax[0] if use_rej else None,
        )
        qfields = tuple(f[0] for f in qfields)
        dfields = tuple(f[0] for f in dfields)
        qcount, qdropped, dcount = qcount[0], qdropped[0], dcount[0]
        local = CSRGraph(indptr=indptr, indices=iloc, weights=wts)
        nloc = indptr.shape[0] - 2
        dev = DevicePartition(
            graph=local, indices_global=iglob,
            vertex_lo=vlo0, vertex_hi=vlo0 + nloc,
        )
        padded = bk.pad_walk_csr(iglob, bias, buckets)

        def do_round(carry):
            q, defer, walks = carry
            # throttle the pop so (deferred + newly stepped) fits one batch
            entries, taken, q = ex.queue_pop(q, cap, limit=cap - defer.count)
            v, inst, d = entries[0], entries[1], entries[2]
            prev = entries[3]
            prow = entries[4] if needs_prev else None
            valid = inst >= 0
            curq = jnp.where(valid, dev.localize(v), -1)

            # -- one walk step, on the engine's exact counted RNG stream ----
            def u_draw(kd):  # fold_in(kstep, 1) -> fold_in(·, 0): bucket pick
                return jax.random.uniform(
                    jax.random.fold_in(jax.random.fold_in(kd, 1), 0),
                    (num_inst,), dtype=jnp.float32)

            def tail_draw(kd):  # fold_in(kstep, 1) -> fold_in(·, 1): tail
                return jax.random.uniform(
                    jax.random.fold_in(jax.random.fold_in(kd, 1), 1),
                    (num_inst,), dtype=jnp.float32)

            r0 = _per_entry(key, d, inst, valid, u_draw)
            tail = _per_entry(key, d, inst, valid, tail_draw) if use_chunked else None
            if mode == "flat" and methods:
                # adaptive selection (DESIGN.md §13): the plan was computed
                # from the SAME full-graph bias as the in-memory engine, so
                # supplying the engine's counted streams (instance-indexed)
                # keeps the sharded walk bit-identical per method
                rej = None
                if use_rej:
                    def rej_draw(c):
                        def drawfn(kd):  # fold_in(kstep,1) -> fold_in(·,2) -> c
                            return jax.random.uniform(
                                jax.random.fold_in(jax.random.fold_in(
                                    jax.random.fold_in(kd, 1), 2), c),
                                (num_inst,), dtype=jnp.float32)
                        return drawfn

                    cols = [
                        _per_entry(key, d, inst, valid, rej_draw(c))
                        for c in range(2 * sel.REJECT_ITERS)
                    ]
                    rej = jnp.stack(cols, axis=-1).reshape(
                        cols[0].shape + (sel.REJECT_ITERS, 2)
                    )
                u = bk.walk_step_adaptive(
                    key, indptr, iglob, bias, padded, curq,
                    buckets=buckets, use_chunked=use_chunked,
                    methods=methods, tables=tbl, backend=be,
                    rand=r0, tail_rand=tail, rej_rand=rej,
                )
            elif mode == "flat":
                if be == "pallas":
                    u = bk.walk_step_bucketed(
                        key, indptr, iglob, bias, padded, curq,
                        buckets=buckets, use_chunked=use_chunked,
                        rand=r0, tail_rand=tail,
                    )
                else:
                    u = bk.walk_step_flat_reference(
                        key, indptr, iglob, bias, padded, curq,
                        buckets=buckets, use_chunked=use_chunked,
                        max_degree=None, rand=r0, tail_rand=tail,
                    )
            else:
                bias_of = _carried_window_bias(local, program, v, prev, d, curq, prow)
                u = bk.walk_step_bucketed_window(
                    key, indptr, iglob, wts, padded, curq, bias_of,
                    buckets=buckets, use_chunked=use_chunked, backend=be,
                    rand=r0, tail_rand=tail,
                )

            # -- epilogue (engine's fused post-select step, instance-keyed) --
            epi = program.epilogue
            if isinstance(epi, tp.TeleportEpilogue):
                def tel_draw(kd):
                    kj, _ = jax.random.split(jax.random.fold_in(kd, 2))
                    return jax.random.uniform(kj, (num_inst,))

                teleport = _per_entry(key, d, inst, valid, tel_draw) < epi.prob
                if epi.target == "uniform":
                    def tgt_draw(kd):
                        _, kv = jax.random.split(jax.random.fold_in(kd, 2))
                        return jax.random.randint(
                            kv, (num_inst,), 0, epi.num_vertices)

                    tgt = _per_entry(key, d, inst, valid, tgt_draw)
                elif epi.target == "fixed":
                    tgt = jnp.full_like(u, epi.vertex)
                else:  # "home"
                    tgt = seeds[jnp.maximum(inst, 0)].astype(jnp.int32)
                nxt = jnp.where(teleport & (u >= 0), tgt, u)
            else:  # IdentityEpilogue (MH/opaque rejected upstream)
                nxt = u
            nxt = jnp.where(u >= 0, nxt, -1)

            ok = valid & (nxt >= 0)
            walks = walks.at[
                jnp.where(ok, inst, num_inst), jnp.maximum(d, 0) + 1
            ].set(nxt, mode="drop")
            cont = ok & (d + 1 < limits[jnp.maximum(inst, 0)])

            # -- route survivors to their new owner ------------------------
            new_entry = [nxt, inst, d + 1, v]
            if needs_prev:
                # the NEXT step's is_prev_neighbor needs N(v): gather v's
                # row here, the one shard that owns it, and carry it along
                offs = jnp.arange(prow_w, dtype=jnp.int32)
                st = indptr[jnp.maximum(curq, 0)]
                dgv = _degree(local, curq)
                rmask = (offs[None, :] < dgv[:, None]) & valid[:, None]
                new_entry.append(
                    jnp.where(rmask, iglob[jnp.where(rmask, st[:, None] + offs, 0)], -2)
                )
            dmask = jnp.arange(cap, dtype=jnp.int32) < defer.count
            cand = tuple(
                jnp.concatenate([df, ne], axis=0)
                for df, ne in zip(defer.fields, new_entry)
            )
            cand_valid = jnp.concatenate([dmask, cont])
            dest = pid_of_device(cand[0], range_size, num_dest)
            send, _sent, leftover, left_count = ex.route_by_owner(
                cand, dest, cand_valid, num_dest, slots
            )
            recv = ex.all_to_all_fields(send, axis)
            rflat = tuple(r.reshape((num_dest * slots,) + r.shape[2:]) for r in recv)
            q = ex.queue_push(q, rflat, rflat[1] >= 0)
            defer = ex.ShardQueue(
                tuple(f[:cap] for f in leftover), left_count, defer.dropped
            )
            return q, defer, walks

        def round_step(carry, _):
            q, defer, walks = carry
            live = jax.lax.psum(q.count + defer.count, axis)
            carry = jax.lax.cond(
                live > 0, do_round, lambda c: c, (q, defer, walks)
            )
            return carry, None

        q0 = ex.ShardQueue(qfields, qcount, qdropped)
        d0 = ex.ShardQueue(dfields, dcount, jnp.zeros((), jnp.int32))
        (q, defer, walks), _ = jax.lax.scan(
            round_step, (q0, d0, walks), None, length=rounds
        )
        live = jax.lax.psum(q.count + defer.count, axis)
        walks = jax.lax.pmax(walks, axis)
        return (
            tuple(f[None] for f in q.fields), q.count[None], q.dropped[None],
            tuple(f[None] for f in defer.fields), defer.count[None],
            walks, live,
        )

    dshard = P(axis)
    rep = P()
    in_specs = (
        dshard, dshard, dshard, dshard, dshard, dshard,  # graph arrays
        dshard, dshard, dshard,                          # method tables
        (dshard,) * nfields, dshard, dshard,             # queue
        (dshard,) * nfields, dshard,                     # deferred
        rep, rep, rep, rep,                              # walks, key, seeds, limits
    )
    out_specs = (
        (dshard,) * nfields, dshard, dshard,
        (dshard,) * nfields, dshard,
        rep, rep,
    )
    fn = jax.jit(
        shard_map_compat(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )
    _DRAIN_CACHE[cfg] = fn
    return fn


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def sharded_random_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
    backend: bk.Backend = "auto",
    depth_limits: Optional[np.ndarray] = None,
    exchange_slots: Optional[int] = None,
    queue_capacity: Optional[int] = None,
    rounds_per_block: Optional[int] = None,
) -> WalkResult:
    """Random walk over a range-sharded graph: owners step, emigrants route.

    Each device of ``mesh`` (along ``axis``) holds one vertex-range shard of
    ``graph`` — per-device CSR footprint ∝ 1/D — and walkers migrate to the
    shard owning their frontier vertex each step.  For flat- and window-bias
    transition programs the result is **bit-identical** to single-device
    ``engine.random_walk(graph, seeds, key, ...)`` with the same arguments,
    on both backends (the parity contract in the module docstring; for
    window programs ``max_degree`` must be the true max row degree, the same
    contract the engine's exact window bucket plan already imposes).
    Unsupported programs fall back to :func:`replicated_psum_walk`.

    ``depth_limits`` (optional ``(W,)``, values in ``[0, depth]``) stops
    instance ``i`` after its own number of steps — the batched service packs
    heterogeneous requests into one launch with it.  ``-1`` seeds are
    padding and emit all--1 rows.

    ``exchange_slots`` bounds the per-destination send buffer of one round;
    walkers past it are deferred to later rounds, never dropped (the queue
    itself defaults to holding the whole walker population, so ``dropped``
    stays zero).  ``rounds_per_block`` sizes the compiled scan; the host
    re-invokes the block while any shard still holds live walkers.
    """
    program = tp.lower(spec)
    mode = program.mode
    epi_ok = isinstance(program.epilogue, (tp.IdentityEpilogue, tp.TeleportEpilogue))
    bias_ok = mode == "flat" or (mode == "window" and not program.bias.needs_deg_u)
    seeds_np = np.asarray(seeds, dtype=np.int32)
    num_inst = int(seeds_np.shape[0])
    if depth_limits is None:
        limits_np = np.full((num_inst,), depth, np.int32)
    else:
        limits_np = np.asarray(depth_limits, dtype=np.int32)
        if limits_np.shape != (num_inst,):
            raise ValueError(
                f"depth_limits shape {limits_np.shape} != ({num_inst},)"
            )
        if limits_np.size and (limits_np.min() < 0 or limits_np.max() > depth):
            raise ValueError(
                f"depth_limits must lie in [0, depth={depth}], got "
                f"[{limits_np.min()}, {limits_np.max()}]"
            )

    if not (epi_ok and bias_ok):
        walks = replicated_psum_walk(
            mesh, graph, jnp.asarray(seeds_np), key,
            depth=depth, spec=spec, max_degree=max_degree, axis=axis,
        )
        walks = jnp.where(
            jnp.arange(depth + 1)[None, :] <= jnp.asarray(limits_np)[:, None],
            walks, -1,
        )
        lengths = jnp.sum(walks >= 0, axis=-1)
        return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))

    if depth < 1 or num_inst == 0:
        walks = jnp.full((num_inst, depth + 1), -1, jnp.int32)
        if num_inst:
            walks = walks.at[:, 0].set(jnp.asarray(seeds_np))
        lengths = jnp.sum(walks >= 0, axis=-1)
        return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))

    num_devices = int(mesh.shape[axis])
    be = bk.resolve_backend(backend)
    if mode == "flat":
        buckets, use_chunked = bk.walk_bucket_plan(max_degree)
    else:
        buckets, use_chunked = bk.walk_bucket_plan_window(max_degree)
    seg_big = max(buckets)
    pm = PartitionMap.create(graph.num_vertices, num_devices)
    parts = partition_by_vertex_range(graph, num_devices)
    needs_prev = mode == "window" and program.bias.needs_prev_neighbors
    indptr_np = np.asarray(graph.indptr)
    prow_w = int(np.diff(indptr_np).max()) if needs_prev else 0

    # -- materialize shards: common padded shape, global block alignment ----
    pad_v = pm.range_size
    pad_e = max((p.edge_lo % seg_big) + p.num_edges for p in parts)
    devs = [
        p.to_local_device_csr(pad_vertices=pad_v, pad_edges=pad_e, edge_align=seg_big)
        for p in parts
    ]
    if mode == "flat":
        # flat biases may read non-resident state (e.g. neighbor degrees):
        # evaluate ONCE on the full graph, slice per shard — bit-equal to the
        # engine's full-graph evaluation by construction
        fb_full = np.asarray(program.bias.fn(graph), dtype=np.float32)
        bias_np = np.zeros((num_devices, pad_e), np.float32)
        for i, p in enumerate(parts):
            lead = p.edge_lo % seg_big
            bias_np[i, lead : lead + p.num_edges] = fb_full[
                p.edge_lo : p.edge_lo + p.num_edges
            ]
        bias_s = jnp.asarray(bias_np)
    else:
        bias_s = jnp.stack([d.graph.weights for d in devs])

    # -- adaptive selection plan (DESIGN.md §13): planned from the SAME
    # full-graph bias as the in-memory engine (same cache entry), so the
    # method per cohort — and therefore every drawn bit — matches
    # single-device random_walk exactly.  Tables are sliced per shard the
    # way the bias is: alias redirects are row-local (row slicing preserves
    # them) and the lead padding keeps global block alignment.
    sel_methods: tuple = ()
    tables_full = mt.EMPTY_TABLES
    if mode == "flat":
        sel_methods, tables_full = flat_method_plan(graph, program, max_degree)
        if mt.is_trivial(sel_methods):
            sel_methods = ()
    prob_np = np.zeros((num_devices, pad_e), np.float32)
    alias_np = np.zeros((num_devices, pad_e), np.int32)
    rowmax_np = np.zeros((num_devices, pad_v + 1), np.float32)
    if tables_full.prob is not None:
        prob_full = np.asarray(tables_full.prob)
        alias_full = np.asarray(tables_full.alias)
        for i, p in enumerate(parts):
            lead = p.edge_lo % seg_big
            sl = slice(lead, lead + p.num_edges)
            prob_np[i, sl] = prob_full[p.edge_lo : p.edge_lo + p.num_edges]
            alias_np[i, sl] = alias_full[p.edge_lo : p.edge_lo + p.num_edges]
    if tables_full.row_max is not None:
        rm_full = np.asarray(tables_full.row_max)
        for i, p in enumerate(parts):
            rowmax_np[i, : p.num_vertices] = rm_full[p.vertex_lo : p.vertex_hi]

    shardspec = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    put_s = functools.partial(jax.device_put, device=shardspec)
    indptr_s = put_s(jnp.stack([d.graph.indptr for d in devs]))
    iloc_s = put_s(jnp.stack([d.graph.indices for d in devs]))
    iglob_s = put_s(jnp.stack([d.indices_global for d in devs]))
    wts_s = put_s(jnp.stack([d.graph.weights for d in devs]))
    bias_s = put_s(bias_s)
    vlo_s = put_s(jnp.asarray([p.vertex_lo for p in parts], jnp.int32))
    prob_s = put_s(jnp.asarray(prob_np))
    alias_s = put_s(jnp.asarray(alias_np))
    rowmax_s = put_s(jnp.asarray(rowmax_np))

    walks0 = np.full((num_inst, depth + 1), -1, np.int32)
    walks0[:, 0] = seeds_np

    # -- initial queues: every live seed starts at its owner ----------------
    cap = num_inst if queue_capacity is None else int(queue_capacity)
    if cap < 1:
        raise ValueError(f"queue_capacity must be >= 1, got {cap}")
    slots = cap if exchange_slots is None else int(exchange_slots)
    if slots < 1:
        raise ValueError(f"exchange_slots must be >= 1, got {slots}")
    slots = min(slots, cap)
    widths = (0, 0, 0, 0) + ((prow_w,) if needs_prev else ())
    live0 = (seeds_np >= 0) & (limits_np > 0)
    owners = pm.pid_of(np.maximum(seeds_np, 0))
    qf0 = [
        np.full((num_devices, cap) if w == 0 else (num_devices, cap, w),
                -1 if w == 0 else -2, np.int32)
        for w in widths
    ]
    qc0 = np.zeros((num_devices,), np.int32)
    for dv in range(num_devices):
        idxs = np.nonzero(live0 & (owners == dv))[0].astype(np.int32)
        k = len(idxs)
        if k > cap:
            raise ValueError(
                f"queue_capacity={cap} cannot hold the {k} seeds owned by "
                f"shard {dv}; raise queue_capacity (default: num instances)"
            )
        qf0[0][dv, :k] = seeds_np[idxs]
        qf0[1][dv, :k] = idxs
        qf0[2][dv, :k] = 0
        qf0[3][dv, :k] = -1
        qc0[dv] = k

    qfields = tuple(put_s(jnp.asarray(f)) for f in qf0)
    qcount = put_s(jnp.asarray(qc0))
    qdropped = put_s(jnp.zeros((num_devices,), jnp.int32))
    dfields = tuple(
        put_s(jnp.full((num_devices, cap) if w == 0 else (num_devices, cap, w),
                       -1 if w == 0 else -2, jnp.int32))
        for w in widths
    )
    dcount = put_s(jnp.zeros((num_devices,), jnp.int32))
    walks = jax.device_put(jnp.asarray(walks0), rep)
    seeds_d = jax.device_put(jnp.asarray(seeds_np), rep)
    limits_d = jax.device_put(jnp.asarray(limits_np), rep)
    key = jax.device_put(key, rep)

    rounds = int(rounds_per_block) if rounds_per_block else depth + 1
    drain = _drain_block(
        mesh, axis, spec=spec, be=be, num_devices=num_devices,
        num_inst=num_inst, depth=depth, cap=cap, slots=slots, prow_w=prow_w,
        buckets=buckets, use_chunked=use_chunked, rounds=max(rounds, 1),
        range_size=pm.range_size, methods=sel_methods,
    )

    blocks = 0
    while True:
        qfields, qcount, qdropped, dfields, dcount, walks, live = drain(
            indptr_s, iloc_s, iglob_s, wts_s, bias_s, vlo_s,
            prob_s, alias_s, rowmax_s,
            qfields, qcount, qdropped, dfields, dcount,
            walks, key, seeds_d, limits_d,
        )
        blocks += 1
        if int(jax.device_get(live)) == 0:
            break
        if blocks >= _MAX_BLOCKS:
            raise RuntimeError(
                f"sharded drain made no global progress after {blocks} "
                f"blocks — exchange_slots={slots} too small?"
            )
    dropped = int(np.sum(jax.device_get(qdropped)))
    if dropped:
        raise RuntimeError(
            f"sharded frontier queues dropped {dropped} walkers — "
            f"queue_capacity={cap} is below the live walker population"
        )
    lengths = jnp.sum(walks >= 0, axis=-1)
    return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))


# ---------------------------------------------------------------------------
# Replicated-state fallback (the pre-exchange design) + shard staging helper
# ---------------------------------------------------------------------------


def shard_graph_for_mesh(graph: CSRGraph, num_devices: int):
    """Range-partition a CSR into per-device stacked full-V-indptr CSRs.

    Returns (indptr_stack (D, V+1), indices_stack (D, Emax), weights_stack)
    where each device's slice covers the full vertex-id space with empty rows
    for unowned vertices (so global ids index directly) and edge arrays are
    padded to the max partition size.  Only the :func:`replicated_psum_walk`
    fallback uses this layout; the owner-routed path ships compact
    ``DevicePartition`` CSRs instead (O(V/D + E_D), DESIGN.md §12).
    """
    parts = partition_by_vertex_range(graph, num_devices)
    v = graph.num_vertices
    emax = max(p.num_edges for p in parts)
    indptrs, indices, weights = [], [], []
    for p in parts:
        full = np.zeros(v + 1, np.int32)
        full[p.vertex_lo + 1 : p.vertex_hi + 1] = p.indptr[1:]
        full[p.vertex_hi + 1 :] = p.indptr[-1]
        indptrs.append(full)
        indices.append(np.pad(p.indices, (0, emax - p.num_edges), constant_values=0).astype(np.int32))
        weights.append(np.pad(p.weights, (0, emax - p.num_edges)).astype(np.float32))
    return (
        jnp.asarray(np.stack(indptrs)),
        jnp.asarray(np.stack(indices)),
        jnp.asarray(np.stack(weights)),
    )


def replicated_psum_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> jax.Array:
    """Walk over a device-sharded graph: owners advance, ``psum`` merges.

    Returns walks (I, depth+1).  Per step each device computes successors for
    walkers whose current vertex it owns (others contribute zeros) and a
    single integer psum replicates the advanced state.  The general-program
    fallback of :func:`sharded_random_walk`: it runs ANY spec (the dense
    gather evaluates opaque hooks; every device sees all walker state, so
    MH-accept can read local degrees for its own vertices), at the cost of
    replicated walker state and one psum per step, and it draws its own RNG
    pattern (not parity-exact with the single-device engine).
    """
    ndev = mesh.shape[axis]
    nvert = graph.num_vertices
    program = tp.lower(spec)
    indptr_s, indices_s, weights_s = shard_graph_for_mesh(graph, ndev)
    # same cached bounds the partitioner used — lo/hi must match the shards
    bounds = PartitionMap.create(nvert, ndev).bounds.astype(np.int32)
    lo = jnp.asarray(bounds[:-1])
    hi = jnp.asarray(bounds[1:])

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=P(),
    )
    def _run(indptr, indices, wts, lo, hi, seeds, key):
        local = CSRGraph(indptr[0], indices[0], wts[0])
        lo0, hi0 = lo[0], hi[0]
        home = seeds.astype(jnp.int32) if program.carries_home else None

        def step(carry, it):
            cur, prev = carry
            own = (cur >= lo0) & (cur < hi0)
            safe = jnp.where(own, cur, lo0)  # in-range dummy for gathers
            ctx, mask = _edge_ctx(local, safe, prev, it, max_degree, spec.needs_prev_neighbors)
            biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
            kstep = jax.random.fold_in(key, it)  # same key on all devices
            idx = sel.select_with_replacement(jax.random.fold_in(kstep, 1), biases, mask, 1)[..., 0]
            u = jnp.take_along_axis(ctx.u, idx[..., None], axis=-1)[..., 0]
            alive = own & (cur >= 0) & jnp.any(mask, axis=-1)
            # post-select update through the lowered epilogue (shared with
            # the in-memory engines and the OOM drain, DESIGN.md §10)
            u = jnp.where(
                alive,
                tp.apply_epilogue(
                    jax.random.fold_in(kstep, 2), program, spec, ctx, u, home
                ),
                -1,
            )
            contrib = jnp.where(own, jnp.where(alive, u, -1), 0)
            dead = jax.lax.psum(jnp.where(own, jnp.where(alive, 0, 1), 0), axis)
            nxt = jax.lax.psum(contrib, axis)  # exactly one owner contributes
            nxt = jnp.where((dead > 0) | (cur < 0), -1, nxt)
            return (nxt, cur), nxt

        (_, _), path = jax.lax.scan(
            step, (seeds.astype(jnp.int32), jnp.full(seeds.shape, -1, jnp.int32)), jnp.arange(depth)
        )
        return jnp.concatenate([seeds[None].astype(jnp.int32), path], 0).T

    return _run(indptr_s, indices_s, weights_s, lo, hi, seeds, key)
