"""GQA attention with exact-causal blocked online softmax.

Design (DESIGN.md §6): attention stays in XLA-visible JAX so the dry-run
``cost_analysis()`` captures true FLOPs/bytes.  To keep 32k-512k sequences
inside HBM we use flash-style blocking, and to avoid the usual 2× masked-FLOP
overcount we exploit that block pairs are *static*: a python-unrolled loop
over q chunks gives each q chunk its own inner ``lax.scan`` over exactly the
kv chunks it can see (causal prefix, or the sliding window) — exact FLOPs,
static shapes, bounded VMEM/HBM transients.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, ashard, model_divides, rms_norm, rope, rp_einsum, softcap

NEG_INF = -1e30


def pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of ``s`` not exceeding ``chunk`` (exact blocking)."""
    c = min(chunk, s)
    while s % c:
        c -= 1
    return c


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.use_qk_norm:
        defs["qnorm"] = ParamDef((hd,), (None,), init="zeros")
        defs["knorm"] = ParamDef((hd,), (None,), init="zeros")
    return defs


def _qkv(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    q = ashard(jnp.einsum("bsd,dhk->bshk", x, params["wq"]), "batch", None, "model", None)
    k = ashard(jnp.einsum("bsd,dhk->bshk", x, params["wk"]), "batch", None, "model", None)
    v = ashard(jnp.einsum("bsd,dhk->bshk", x, params["wv"]), "batch", None, "model", None)
    if cfg.use_qk_norm:
        q = rms_norm(q, params["qnorm"], cfg.norm_eps)
        k = rms_norm(k, params["knorm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_pair(
    q_blk: jax.Array,  # (B, Cq, H, Dh)
    k_span: jax.Array,  # (B, n, Ckv, H, Dh) — KV already repeated to H heads
    v_span: jax.Array,
    q_pos: jax.Array,  # (Cq,)
    kv_pos: jax.Array,  # (n, Ckv)
    *,
    scale: float,
    window: int,
    cap: float,
    heads_ok: bool = True,
    scores_dtype=jnp.float32,
):
    """Online-softmax accumulate q block against its kv span (scan over n).

    KV is pre-repeated to the full head count so the score tensors carry the
    sharded ``heads`` dim even when kv_heads doesn't divide the model axis
    (GQA reshape would otherwise force replication — a 16× activation blowup
    on archs like internlm2 kv=8 on a model=16 mesh).
    """
    b, cq, h, dh = q_blk.shape
    # when heads don't divide the model axis (xlstm 4H, musicgen 24H, ...)
    # shard the q-chunk dim instead — sequence-block parallelism: scores
    # (B, H, Cq/model, Ckv) stay distributed, kv chunks replicate (small).
    if heads_ok:
        shd_q = lambda t: ashard(t, "batch", None, "model", None)
        shd_s = lambda t: ashard(t, "batch", "model", None)  # (B,H,Cq)
        shd_a = lambda t: ashard(t, "batch", "model", None, None)
    else:
        shd_q = lambda t: ashard(t, "batch", "model", None, None)
        shd_s = lambda t: ashard(t, "batch", None, "model")
        shd_a = lambda t: ashard(t, "batch", None, "model", None)
    q_blk = shd_q(q_blk)

    neg_big = jnp.asarray(NEG_INF if scores_dtype == jnp.float32 else -3e38 / 1e4, scores_dtype)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, pos = xs  # (B,Ckv,H,Dh), (B,Ckv,H,Dh), (Ckv,)
        s = jnp.einsum(
            "bqhd,bchd->bhqc", q_blk, kc, preferred_element_type=scores_dtype
        ) * jnp.asarray(scale, scores_dtype)
        s = softcap(s, cap)
        msk = pos[None, :] <= q_pos[:, None]  # causal (Cq, Ckv)
        if window > 0:
            msk &= pos[None, :] > q_pos[:, None] - window
        s = jnp.where(msk[None, None], s, neg_big)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(scores_dtype)
        p = jnp.where(msk[None, None], p, jnp.asarray(0.0, scores_dtype))
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p.astype(kc.dtype), vc, preferred_element_type=jnp.float32
        )
        return (m_new, l, acc), None

    m0 = shd_s(jnp.full((b, h, cq), NEG_INF, jnp.float32))
    l0 = shd_s(jnp.zeros((b, h, cq), jnp.float32))
    a0 = shd_a(jnp.zeros((b, h, cq, dh), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k_span.swapaxes(0, 1), v_span.swapaxes(0, 1), kv_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out  # (B, H, Cq, Dh)


def blocked_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, KVH, Dh)
    v: jax.Array,
    cfg: ModelConfig,
    *,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = dh**-0.5
    cq = ckv = pick_chunk(s, cfg.attn_chunk)
    nq = s // cq
    if g > 1:  # repeat KV to full heads: q head i uses kv head i // g
        k = ashard(jnp.repeat(k, g, axis=2), "batch", None, "model", None)
        v = ashard(jnp.repeat(v, g, axis=2), "batch", None, "model", None)
    qg = q.reshape(b, nq, cq, h, dh)
    kc = k.reshape(b, s // ckv, ckv, h, dh)
    vc = v.reshape(b, s // ckv, ckv, h, dh)
    outs = []
    for qi in range(nq):
        q_lo = qi * cq
        if window > 0:
            ki_lo = max(0, (q_lo - window) // ckv)
        else:
            ki_lo = 0
        ki_hi = (q_lo + cq - 1) // ckv  # inclusive
        n = ki_hi - ki_lo + 1
        q_pos = q_offset + q_lo + jnp.arange(cq)
        kv_pos = (
            q_offset
            + (ki_lo * ckv)
            + jnp.arange(n * ckv).reshape(n, ckv)
        )
        out = _block_pair(
            qg[:, qi],
            jax.lax.slice_in_dim(kc, ki_lo, ki_hi + 1, axis=1),
            jax.lax.slice_in_dim(vc, ki_lo, ki_hi + 1, axis=1),
            q_pos,
            kv_pos,
            scale=scale,
            window=window,
            cap=cfg.attn_softcap,
            heads_ok=model_divides(h),
            scores_dtype=jnp.bfloat16 if cfg.attn_scores_dtype == "bf16" else jnp.float32,
        )
        outs.append(out)
    out = jnp.stack(outs, axis=1)  # (B, nq, H, Cq, Dh)
    out = out.transpose(0, 1, 3, 2, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def attention_train(
    params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array, *, window: int = 0
) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    q, k, v = _qkv(params, cfg, x, positions)
    out = blocked_attention(q, k, v, cfg, window=window)
    return rp_einsum("bshk,hkd->bsd", out, params["wo"], cfg.reduce_dtype)


def attention_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, D)
    cache_k: jax.Array,  # (B, S_max, KVH, Dh)
    cache_v: jax.Array,
    cache_index: jax.Array,  # () int32 — # tokens already in cache
    *,
    window: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache. Returns (out, new_k, new_v)."""
    b, _, d = x.shape
    positions = jnp.full((b, 1), cache_index, jnp.int32)
    q, k, v = _qkv(params, cfg, x, positions)
    s_max = cache_k.shape[1]
    if window > 0:
        # ring buffer for sliding-window layers: KV footprint O(window)
        slot = jnp.mod(cache_index, s_max)
    else:
        slot = jnp.minimum(cache_index, s_max - 1)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    kvh = cache_k.shape[2]
    g = q.shape[2] // kvh
    qh = q.reshape(b, 1, kvh, g, -1)
    s = jnp.einsum(
        "bqkgd,bckd->bkgqc", qh, cache_k, preferred_element_type=jnp.float32
    ) * (cfg.head_dim**-0.5)
    s = softcap(s, cfg.attn_softcap)
    kv_pos = jnp.arange(s_max)
    if window > 0:
        # ring buffer sized to the window: every written slot is in range
        msk = kv_pos < jnp.minimum(cache_index + 1, s_max)
    else:
        msk = kv_pos <= cache_index
    s = jnp.where(msk[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(cache_v.dtype), cache_v)
    out = out.reshape(b, 1, -1, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache_k, cache_v
