"""Shared case generators for the cross-engine property-parity harness.

Three layers, so the harness degrades gracefully:

- **Deterministic builders** (`GRAPH_BUILDERS`, `SPEC_BUILDERS`,
  `build_graph` / `build_spec`): plain cached callables.  Caching matters
  twice over — the same ``CSRGraph`` / ``SamplingSpec`` OBJECT is reused
  across cases, so the engines' jit caches (which key on spec identity and
  array shapes) actually hit, keeping the whole suite to a handful of
  traces.
- **Seed corpus** (`SEED_CORPUS`): named cases that ALWAYS run (no
  hypothesis needed), parametrized straight into the parity tests.  The
  graph family mirrors the BENCH configs (``powerlaw_graph`` with the
  fig17 generator parameters, weighted, CI-scaled sizes) plus the
  adversarial shapes: a star (one hub owns every edge — the hub-replication
  and exchange-pressure worst case) and a ring (pure cross-shard chain).
- **Hypothesis strategies** (`graph_cases`, `spec_cases`, `walk_cases`):
  random (graph × spec × method override × depth × seed-set) draws over the
  same cached builders.  Only defined when hypothesis is installed
  (`HAS_HYPOTHESIS`); CI installs the ``[test]`` extra, so they run
  blocking there.

`REGRESSION_CASES` is the failure registry: when a property test finds a
counterexample, pin it here (same shape as `SEED_CORPUS` entries) so it
reruns forever as a plain parametrized case.  Seeded with the cases that
exercised the paths the hub-replication PR moved off the replicated-psum
fallback (MH-accept and ``needs_deg_u`` window biases).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core.api import SamplingSpec
from repro.core.transition import TransitionProgram, WindowBias
from repro.graph import csr_from_edges, powerlaw_graph

try:  # pragma: no cover - exercised via HAS_HYPOTHESIS guards
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    st = None
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def _powerlaw(num_vertices: int, seed: int, weighted: bool):
    # the BENCH family: fig17_scaling.py's generator with default exponent /
    # degree bounds, scaled down for CI
    return powerlaw_graph(num_vertices, seed=seed, weighted=weighted)


@functools.lru_cache(maxsize=8)
def _star(num_vertices: int):
    # one hub owns (almost) every edge: the worst case for owner routing —
    # every walker funnels into the hub's shard every other step — and the
    # best case for hub replication
    spokes = np.arange(1, num_vertices, dtype=np.int64)
    hub = np.zeros_like(spokes)
    return csr_from_edges(num_vertices, hub, spokes, symmetrize=True)


@functools.lru_cache(maxsize=8)
def _ring(num_vertices: int):
    # degree-2 everywhere: zero hubs, maximal cross-shard chain traffic
    src = np.arange(num_vertices, dtype=np.int64)
    dst = (src + 1) % num_vertices
    return csr_from_edges(num_vertices, src, dst, symmetrize=True)


GRAPH_BUILDERS = {
    "pl64": lambda: _powerlaw(64, 0, False),
    "pl130w": lambda: _powerlaw(130, 1, True),
    "pl300w": lambda: _powerlaw(300, 3, True),
    "star33": lambda: _star(33),
    "star65": lambda: _star(65),
    "ring48": lambda: _ring(48),
}


def build_graph(name: str):
    return GRAPH_BUILDERS[name]()


# ---------------------------------------------------------------------------
# Sampling specs
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _degu_window_spec() -> SamplingSpec:
    # a window bias that READS the candidate's degree — the program family
    # that used to force the replicated-psum fallback on the sharded path
    wb = WindowBias(
        lambda ctx: ctx.weight / jnp.maximum(ctx.deg_u, 1), needs_deg_u=True
    )
    return SamplingSpec(name="degu_window", transition=TransitionProgram(bias=wb))


_SPEC_FACTORIES = {
    "deepwalk": alg.deepwalk,
    "weighted": alg.weighted_random_walk,
    "node2vec": lambda: alg.node2vec(p=2.0, q=0.5),
    "mh": alg.metropolis_hastings_walk,
    "restart": lambda: alg.random_walk_with_restart(0.2),
    "degu_window": _degu_window_spec,
}


@functools.lru_cache(maxsize=64)
def build_spec(name: str, method: Optional[str] = None) -> SamplingSpec:
    """One cached spec object per (family, selection-method override)."""
    spec = _SPEC_FACTORIES[name]()
    if method is not None:
        spec = dataclasses.replace(spec, selection_method=method)
    return spec


#: flat-bias families accept a selection-method override (DESIGN.md §13);
#: window/epilogue families ignore it, so only combine where it's meaningful
FLAT_SPECS = ("deepwalk", "weighted", "mh", "restart")
SPEC_BUILDERS = tuple(_SPEC_FACTORIES)
METHOD_OVERRIDES = (None, "its", "alias", "rejection")


# ---------------------------------------------------------------------------
# Cases
# ---------------------------------------------------------------------------


class ParityCase(NamedTuple):
    """One concrete (graph, program, walk geometry) parity check."""

    graph: str  # GRAPH_BUILDERS key
    spec: str  # _SPEC_FACTORIES key
    method: Optional[str]  # selection-method override (flat specs only)
    depth: int
    num_seeds: int
    key_seed: int

    @property
    def label(self) -> str:
        m = f"+{self.method}" if self.method else ""
        return f"{self.graph}-{self.spec}{m}-d{self.depth}"


def case_args(case: ParityCase):
    """Materialize a case: (graph, seeds, spec, max_degree)."""
    g = build_graph(case.graph)
    nv = g.num_vertices
    stride = max(nv // case.num_seeds, 1)
    seeds = np.arange(0, nv, stride, dtype=np.int32)[: case.num_seeds]
    spec = build_spec(case.spec, case.method)
    md = int(np.diff(np.asarray(g.indptr)).max())
    return g, seeds, spec, md


#: always-run corpus: every program family on the BENCH graph family plus
#: the adversarial shapes, with at least one method override per selection
#: method
SEED_CORPUS = [
    ParityCase("pl300w", "deepwalk", None, 9, 32, 0),
    ParityCase("pl300w", "weighted", "alias", 9, 32, 0),
    ParityCase("pl130w", "weighted", "rejection", 7, 16, 1),
    ParityCase("pl64", "deepwalk", "its", 5, 16, 2),
    ParityCase("pl300w", "node2vec", None, 7, 24, 0),
    ParityCase("pl300w", "mh", None, 9, 24, 1),
    ParityCase("pl130w", "degu_window", None, 7, 16, 0),
    ParityCase("pl130w", "restart", None, 7, 16, 0),
    ParityCase("star65", "deepwalk", None, 6, 16, 0),
    ParityCase("star33", "mh", None, 6, 11, 3),
    ParityCase("ring48", "node2vec", None, 8, 12, 0),
]

#: pinned counterexamples from property runs (same shape as SEED_CORPUS —
#: append here when hypothesis finds a failure, never delete).  Seeded with
#: the programs this PR moved off the replicated-psum fallback, on the
#: shapes most likely to break them: MH on a star (every acceptance reads
#: the hub degree) and deg_u-window on a skewed power-law graph.
REGRESSION_CASES = [
    ParityCase("star33", "mh", None, 8, 16, 0),
    ParityCase("pl300w", "degu_window", None, 9, 24, 2),
    ParityCase("star65", "node2vec", None, 7, 16, 1),
]


class StreamCase(NamedTuple):
    """One streaming-arrival parity check: a :class:`ParityCase` whose seed
    set is cut into several requests and fed to the streaming scheduler in
    a randomized arrival pattern (order, inter-arrival gaps, deadlines,
    priorities all derived from ``arrival_seed``).  The contract: no
    arrival pattern may change any request's walks — streaming decides
    only *when* cohorts launch, never what they compute.
    """

    case: ParityCase
    arrival_seed: int

    @property
    def label(self) -> str:
        return f"{self.case.label}-arrival{self.arrival_seed}"


def stream_requests(case: ParityCase, arrival_seed: int, num_requests: int = 3):
    """Cut a case's seed set into per-request submissions plus an arrival
    plan: ``(requests, order)`` where ``requests[i] = (seeds_i, depth_i)``
    and ``order`` is the submission permutation.  Depths vary around the
    case depth so the cut exercises mixed depth buckets; geometry stays on
    the small fixed menus (shared jit caches, as everywhere else here).
    """
    g, seeds, spec, md = case_args(case)
    rng = np.random.default_rng(arrival_seed)
    cuts = [c for c in np.array_split(seeds, num_requests) if len(c)]
    requests = [
        (cut, max(1, case.depth - (i % 2))) for i, cut in enumerate(cuts)
    ]
    order = rng.permutation(len(requests))
    return g, spec, md, requests, order, rng


#: always-run streaming corpus: every arrival pattern over a program mix
#: (flat / window / epilogue) — kept small, the hypothesis pass sweeps wider
STREAM_CORPUS = [
    StreamCase(SEED_CORPUS[0], 0),   # deepwalk, in-order-ish
    StreamCase(SEED_CORPUS[0], 3),   # deepwalk, different arrival pattern
    StreamCase(SEED_CORPUS[4], 1),   # node2vec (window bias, carried prev)
    StreamCase(SEED_CORPUS[5], 2),   # MH epilogue
    StreamCase(SEED_CORPUS[7], 1),   # restart teleport
    StreamCase(SEED_CORPUS[1], 2),   # weighted + alias override
]


# ---------------------------------------------------------------------------
# Hypothesis strategies (present only when hypothesis is installed)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    def graph_cases():
        return st.sampled_from(sorted(GRAPH_BUILDERS))

    @st.composite
    def spec_cases(draw):
        """(spec name, method override) — overrides only where meaningful."""
        name = draw(st.sampled_from(SPEC_BUILDERS))
        method = None
        if name in FLAT_SPECS:
            method = draw(st.sampled_from(METHOD_OVERRIDES))
        return name, method

    @st.composite
    def walk_cases(draw):
        """A full random ParityCase over the cached builders.

        Geometry values come from small fixed menus so the engines' shape-
        keyed jit caches are shared across examples — the point is many
        (program × graph) combinations, not many array shapes.
        """
        gname = draw(graph_cases())
        sname, method = draw(spec_cases())
        depth = draw(st.sampled_from([1, 5, 9]))
        num_seeds = draw(st.sampled_from([3, 16, 32]))
        key_seed = draw(st.integers(min_value=0, max_value=3))
        return ParityCase(gname, sname, method, depth, num_seeds, key_seed)
