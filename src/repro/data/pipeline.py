"""Token data pipeline: deterministic, checkpointable, host-sharded.

``TokenPipeline`` yields fixed-shape (tokens, labels) batches.  State is a
single integer cursor → trivially checkpointable and restorable (exactly
what restart-after-failure needs).  Sources:

  - ``synthetic``   — seeded LCG token stream (tests, dry-runs, benches).
  - ``walk``        — C-SAW random-walk corpus (data/walk_corpus.py): the
    paper's engine is the data plane (DESIGN.md §4).

On a real fleet each host loads ``host_shard`` of every batch; here
host_count=1 and the full batch is produced locally.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineState:
    cursor: int = 0
    epoch: int = 0


class TokenPipeline:
    def __init__(
        self,
        vocab_size: int,
        batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        corpus: Optional[np.ndarray] = None,  # (N, seq_len+1) pre-tokenized
        host_index: int = 0,
        host_count: int = 1,
    ):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.corpus = corpus
        self.host_index = host_index
        self.host_count = host_count
        self.state = PipelineState()
        assert batch % host_count == 0

    # -- checkpoint integration --------------------------------------------
    def state_dict(self) -> dict:
        return {"cursor": self.state.cursor, "epoch": self.state.epoch}

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState(int(d["cursor"]), int(d["epoch"]))

    # -- batches -------------------------------------------------------------
    def _synthetic_batch(self, cursor: int) -> np.ndarray:
        # counter-based: batch i is a pure function of (seed, cursor)
        rng = np.random.default_rng((self.seed, cursor))
        return rng.integers(
            0, self.vocab_size, (self.batch, self.seq_len + 1), dtype=np.int32
        )

    def next(self) -> dict:
        per_host = self.batch // self.host_count
        if self.corpus is not None:
            n = self.corpus.shape[0]
            idx = (self.state.cursor * self.batch + np.arange(self.batch)) % n
            seqs = self.corpus[idx]
            if self.state.cursor * self.batch // max(n, 1) > self.state.epoch:
                self.state.epoch += 1
        else:
            seqs = self._synthetic_batch(self.state.cursor)
        self.state.cursor += 1
        lo = self.host_index * per_host
        seqs = seqs[lo : lo + per_host]
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()
