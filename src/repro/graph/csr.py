"""CSR graph storage as a JAX pytree.

The paper stores graphs in CSR (Table II reports "Size (of CSR)").  We keep
the same layout: ``indptr`` (V+1), ``indices`` (E), optional ``weights`` (E).
All arrays are device arrays so the structure can flow through jit/shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSRGraph:
    """Compressed sparse row graph.

    indptr:  (V+1,) int32 — neighbor list offsets.
    indices: (E,)   int32 — neighbor vertex ids.
    weights: (E,)   float32 — edge weights (all-ones if unweighted).

    INVARIANT: ``indices`` is sorted ascending within each row.  Every
    constructor in ``repro.graph`` guarantees it (``csr_from_edges``
    lexsorts; the generators build through it; partition localization
    preserves row order).  The windowed prev-membership search of the
    transition-program fast path (DESIGN.md §10) binary-searches rows and
    silently misses neighbors on unsorted rows — code that builds a
    CSRGraph directly from raw arrays must sort rows first.
    """

    indptr: jax.Array
    indices: jax.Array
    weights: jax.Array

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.weights), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- basic properties -------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.indices.shape[0]

    def degree(self, v: jax.Array) -> jax.Array:
        """Degree of vertex (or vertices) ``v``."""
        return self.indptr[v + 1] - self.indptr[v]

    def max_degree(self) -> int:
        return int(jnp.max(self.indptr[1:] - self.indptr[:-1]))


def csr_from_edges(
    num_vertices: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    symmetrize: bool = False,
    dedup: bool = True,
) -> CSRGraph:
    """Build a CSRGraph from an edge list (host-side, numpy)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if weights is None:
        w = np.ones(src.shape[0], dtype=np.float32)
    else:
        w = np.asarray(weights, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    # Remove self loops.
    keep = src != dst
    src, dst, w = src[keep], dst[keep], w[keep]
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    if dedup and src.size:
        uniq = np.ones(src.shape[0], dtype=bool)
        uniq[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst, w = src[uniq], dst[uniq], w[uniq]
    counts = np.bincount(src, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(
        indptr=jnp.asarray(indptr, dtype=jnp.int32),
        indices=jnp.asarray(dst, dtype=jnp.int32),
        weights=jnp.asarray(w, dtype=jnp.float32),
    )


def degrees(graph: CSRGraph) -> jax.Array:
    return graph.indptr[1:] - graph.indptr[:-1]


def neighbors_padded(
    graph: CSRGraph, vertices: jax.Array, max_degree: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gather padded neighbor lists for a batch of vertices.

    Returns (neighbors, weights, mask) each of shape vertices.shape+(max_degree,).
    Padded slots hold neighbor=-1, weight=0, mask=False.  Degrees above
    ``max_degree`` are truncated — callers that need exactness route large
    degrees through the chunked path in ``core.select``.
    """
    start = graph.indptr[vertices]
    deg = graph.indptr[vertices + 1] - start
    offs = jnp.arange(max_degree, dtype=jnp.int32)
    idx = start[..., None] + offs
    mask = offs < deg[..., None]
    safe = jnp.where(mask, idx, 0)
    nbrs = jnp.where(mask, graph.indices[safe], -1)
    wts = jnp.where(mask, graph.weights[safe], 0.0)
    return nbrs, wts, mask
