"""Adaptive selection runtime: per-bucket method planning (DESIGN.md §13).

C-SAW's selection engine is pure ITS — O(degree) cumsum per draw.  For a
*static* flat bias that is wasteful: alias tables (``select.build_alias``)
amortize an O(E) build into O(1) draws, and near-uniform biases accept a
rejection-sampled candidate in ~1 round without any table at all.  Neither
helps a *dynamic* window bias (the table/envelope would be stale every
step), so the planner here only ever runs for ``FlatBias`` programs; window
and opaque modes stay on ITS.

The plan is computed HOST-SIDE from concrete bucket statistics (float64
numpy, so every execution path — in-memory, OOM drain, sharded, serving —
derives the identical plan from the same graph+bias) and enters the jitted
step as a static tuple ``methods``: one entry per degree bucket plus one
for the chunked huge-degree tail when present.

Cost model, per cohort (``TransitionProgram.method == "auto"``):

  - empty cohort                                 → ``"its"`` (nothing to draw;
    skips table construction for buckets the graph never populates)
  - any zero-bias edge in the cohort             → ``"alias"`` (rejection
    could burn its whole budget proposing dead edges)
  - mean row uniformity ``mean/max >= 0.75``     → ``"rejection"`` (expected
    rounds ``<= 1/0.75``; the 8-round budget exhausts w.p. ``<= 0.25**8``)
  - otherwise                                    → ``"alias"``

ITS is never auto-picked for a populated flat cohort — with prebuilt tables
both new methods dominate it.  ``method="its"`` (or
``SamplingSpec.selection_method="its"``) forces the legacy behavior.

Alias tables and rejection envelopes are cached per ``(graph, bias_fn)`` in
a small strong-ref LRU so repeated launches — every request the
``SamplingService`` drains — reuse them; that amortization is the headline
serving win benchmarked in BENCH_walk.json.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import select as sel

#: Auto-pick rejection only when the mean row uniformity (row mean bias over
#: row max bias) of a cohort is at least this — acceptance rate >= 0.75.
REJECTION_UNIFORMITY = 0.75

#: Bounded plan/table cache: (id(graph.indices), bias_fn) -> _PlanEntry.
_PLAN_CACHE: "OrderedDict[tuple, _PlanEntry]" = OrderedDict()
_PLAN_CACHE_MAX = 8


class MethodTables(NamedTuple):
    """Prebuilt per-method arrays threaded through the jitted step as a
    pytree.  ``None`` fields are methods the plan never uses (a ``None``
    leaf is static structure, so an all-ITS plan adds nothing to the
    trace)."""

    prob: Optional[jax.Array] = None  # (E,) f32 alias acceptance thresholds
    alias: Optional[jax.Array] = None  # (E,) int32 row-local alias redirects
    row_max: Optional[jax.Array] = None  # (V,) f32 rejection envelopes


EMPTY_TABLES = MethodTables()


def is_trivial(methods: tuple) -> bool:
    """An all-ITS plan — the pre-adaptive fast path, no tables needed."""
    return all(m == "its" for m in methods)


class _PlanEntry:
    """Cached per-(graph, bias) state: host stats + lazily built tables.

    Holds strong refs to the keyed objects so the ``id()`` half of the cache
    key can never be recycled while the entry lives.
    """

    def __init__(self, indices, bias_fn, bias_np, deg):
        self._pins = (indices, bias_fn)
        self.bias_np = bias_np  # (E,) float64, clipped at 0
        self.deg = deg  # (V,) int64
        self._row_stats = None
        self._alias = None
        self._row_max = None
        self.plans: dict = {}

    def row_stats(self, indptr):
        if self._row_stats is None:
            self._row_stats = row_stats(indptr, self.bias_np, self.deg)
        return self._row_stats

    def tables(self, methods, indptr) -> MethodTables:
        prob = alias = row_max = None
        if any(m == "alias" for m in methods):
            if self._alias is None:
                p, a = sel.build_alias(indptr, self.bias_np)
                self._alias = (jnp.asarray(p), jnp.asarray(a))
            prob, alias = self._alias
        if any(m == "rejection" for m in methods):
            if self._row_max is None:
                self._row_max = jnp.asarray(sel.build_row_max(indptr, self.bias_np))
            row_max = self._row_max
        return MethodTables(prob=prob, alias=alias, row_max=row_max)


def row_stats(indptr, bias_np, deg=None):
    """Per-row ``(mean, max, min)`` of a clipped CSR-order bias (host f64).

    Shared by the cached in-memory planner and the OOM drain's per-partition
    pre-pass (which aggregates stats across partitions before planning once).
    Rows of degree 0 report all-zero stats and are excluded by the cost
    model's liveness mask.
    """
    deg = np.diff(np.asarray(indptr)).astype(np.int64) if deg is None else deg
    e = bias_np.shape[0]
    if e == 0:
        z = np.zeros(deg.shape[0])
        return (z, z, z)
    starts = np.minimum(np.asarray(indptr)[:-1], e - 1)
    rmax = np.where(deg > 0, np.maximum.reduceat(bias_np, starts), 0.0)
    rmin = np.where(deg > 0, np.minimum.reduceat(bias_np, starts), 0.0)
    rsum = np.where(deg > 0, np.add.reduceat(bias_np, starts), 0.0)
    return rsum / np.maximum(deg, 1), rmax, rmin


def plan_methods(
    deg,
    row_stats,
    *,
    buckets: tuple,
    use_chunked: bool,
    override: Optional[str] = None,
) -> tuple:
    """The cost model: one method per degree cohort (host numpy, float64)."""
    n = len(buckets) + (1 if use_chunked else 0)
    if override in ("its", "alias", "rejection"):
        return (override,) * n
    rmean, rmax, rmin = row_stats
    methods = []
    for i, seg in enumerate(buckets):
        lo = 0 if i == 0 else buckets[i - 1]
        absorb = i == len(buckets) - 1 and not use_chunked
        rows = (deg > lo) & ((deg <= seg) | absorb)
        methods.append(_pick(rows, rmean, rmax, rmin))
    if use_chunked:
        rows = deg > buckets[-1]
        methods.append(_pick(rows, rmean, rmax, rmin))
    return tuple(methods)


def _pick(rows, rmean, rmax, rmin) -> str:
    live = rows & (rmax > 0.0)
    if not live.any():
        return "its"
    if (rmin[live] <= 0.0).any():
        return "alias"
    uniformity = float(np.mean(rmean[live] / rmax[live]))
    return "rejection" if uniformity >= REJECTION_UNIFORMITY else "alias"


def plan_for_graph(
    graph,
    bias_fn,
    flat_bias=None,
    *,
    buckets: tuple,
    use_chunked: bool,
    override: Optional[str] = None,
) -> tuple:
    """Plan methods for (graph, flat-bias fn) and build/reuse its tables.

    Returns ``(methods, MethodTables)``.  Cached per
    ``(id(graph.indices), bias_fn)`` — the algorithm constructors use
    module-level bias fns, so every ``deepwalk()`` spec on the same graph
    hits the same entry.  ``flat_bias`` optionally supplies the
    already-evaluated concrete ``(E,)`` bias (the OOM drain evaluates it per
    partition anyway); otherwise ``bias_fn(graph)`` is evaluated eagerly.
    ``override="its"`` short-circuits: no stats, no tables.
    """
    n = len(buckets) + (1 if use_chunked else 0)
    if override == "its":
        return ("its",) * n, EMPTY_TABLES
    key = (id(graph.indices), bias_fn)
    entry = _PLAN_CACHE.get(key)
    if entry is None:
        fb = bias_fn(graph) if flat_bias is None else flat_bias
        bias_np = np.maximum(np.asarray(fb, dtype=np.float64), 0.0)
        deg = np.diff(np.asarray(graph.indptr)).astype(np.int64)
        entry = _PlanEntry(graph.indices, bias_fn, bias_np, deg)
        _PLAN_CACHE[key] = entry
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)
    else:
        _PLAN_CACHE.move_to_end(key)
    indptr = np.asarray(graph.indptr)
    plan_key = (tuple(buckets), bool(use_chunked), override)
    methods = entry.plans.get(plan_key)
    if methods is None:
        methods = plan_methods(
            entry.deg,
            entry.row_stats(indptr),
            buckets=tuple(buckets),
            use_chunked=use_chunked,
            override=override,
        )
        entry.plans[plan_key] = methods
    if is_trivial(methods):
        return methods, EMPTY_TABLES
    return methods, entry.tables(methods, indptr)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def describe_plan(methods: tuple, buckets: tuple, use_chunked: bool) -> dict:
    """JSON-friendly per-cohort view for BENCH_walk.json."""
    out = {}
    for i, seg in enumerate(buckets):
        lo = 0 if i == 0 else buckets[i - 1]
        out[f"deg({lo},{seg}]"] = methods[i]
    if use_chunked:
        out[f"deg>{buckets[-1]}"] = methods[len(buckets)]
    return out
