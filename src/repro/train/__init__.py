"""train subpackage."""
