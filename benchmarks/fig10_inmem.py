"""Paper Figs. 10-12: in-memory selection optimizations.

Fig. 10 — speedup of BRS (and the beyond-paper Gumbel mode) over repeated
          and updated sampling, per algorithm.
Fig. 11 — mean retry iterations with vs without BRS.
Fig. 12 — CTPS search-count reduction (conflict-matrix bitmap analogue).
"""
from __future__ import annotations

import jax

from benchmarks.common import BENCH_GRAPHS, row, timeit
from repro.core import algorithms as alg
from repro.core.engine import traversal_sample

ALGOS = {
    "neighbor_biased": lambda: alg.biased_neighbor_sampling(neighbor_size=4, frontier_size=4),
    "neighbor_unbiased": lambda: alg.unbiased_neighbor_sampling(neighbor_size=4, frontier_size=4),
    "forest_fire": lambda: alg.forest_fire_sampling(p_f=0.7, max_burn=6),
    "layer": lambda: alg.layer_sampling(neighbor_size=8, frontier_size=8),
}


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(1)
    g = BENCH_GRAPHS["pl50k"]()
    md = min(g.max_degree(), 512)
    pools = jax.random.randint(key, (1024, 1), 0, g.num_vertices)

    for aname, mk in ALGOS.items():
        spec = mk()
        stats = {}
        for method in ("repeated", "updated", "its_brs", "gumbel"):
            def go(m=method):
                return traversal_sample(
                    g, pools, key, depth=2, spec=spec, max_degree=md,
                    pool_capacity=256, method=m, max_vertices=g.num_vertices,
                )
            secs = timeit(go)
            res = go()
            stats[method] = (secs, int(res.iters), int(res.searches))
        base = stats["repeated"][0]
        rows.append(row(
            f"fig10/{aname}", stats["its_brs"][0] * 1e6,
            f"speedup_brs={base/stats['its_brs'][0]:.2f}x;"
            f"speedup_updated={base/stats['updated'][0]:.2f}x;"
            f"speedup_gumbel={base/stats['gumbel'][0]:.2f}x",
        ))
        rows.append(row(
            f"fig11/{aname}", 0.0,
            f"iters_repeated={stats['repeated'][1]};iters_brs={stats['its_brs'][1]};"
            f"reduction={stats['repeated'][1]/max(stats['its_brs'][1],1):.2f}x",
        ))
        rows.append(row(
            f"fig12/{aname}", 0.0,
            f"searches_repeated={stats['repeated'][2]};searches_brs={stats['its_brs'][2]};"
            f"ratio={stats['its_brs'][2]/max(stats['repeated'][2],1):.2f}",
        ))
    return rows
