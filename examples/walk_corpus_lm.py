"""End-to-end driver: C-SAW random-walk corpus -> decoder-LM pretraining.

The paper's engine is the data plane (DESIGN.md §4): DeepWalk sequences over
a graph are the token stream; any assigned architecture trains on them.
Fault tolerance is live: checkpoints every N steps, restart-from-latest, a
step monitor, and an optional injected failure to demonstrate recovery.

    PYTHONPATH=src python examples/walk_corpus_lm.py --steps 300 --scale 100m
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.data.walk_corpus import build_walk_corpus
from repro.graph import powerlaw_graph
from repro.models import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StepMonitor
from repro.train.optimizer import OptConfig, opt_init
from repro.train.train_step import make_train_step

SCALES = {
    # ~100M-param decoder (the "train a ~100M model" end-to-end driver)
    "100m": dict(num_layers=8, d_model=640, num_heads=8, num_kv_heads=4,
                 head_dim=80, d_ff=2560),
    "10m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                head_dim=64, d_ff=1024),
    "tiny": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=512),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--scale", choices=SCALES, default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/csaw_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    # --- data plane: the paper's sampler --------------------------------------
    g = powerlaw_graph(20_000, exponent=2.1, seed=0, weighted=True)
    corpus = build_walk_corpus(
        g, num_walks=4096, walk_length=args.seq, algorithm="deepwalk",
        seed=1, vocab_size=20_000, max_degree=min(g.max_degree(), 512),
    )
    print(f"walk corpus: {corpus.shape[0]} sequences × {corpus.shape[1]} tokens")

    cfg = ModelConfig(
        name=f"walklm-{args.scale}", family="dense", vocab_size=20_000,
        pattern=("global",), dtype="float32", param_dtype="float32",
        attn_chunk=64, remat="none", **SCALES[args.scale],
    )
    print(f"model: {cfg.param_count()/1e6:.0f}M params")

    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    ocfg = OptConfig(kind="adamw", lr=1e-3, warmup_steps=20)
    step_fn, _ = make_train_step(cfg, ocfg, mesh)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, corpus=corpus)

    mgr = CheckpointManager(args.ckpt_dir, keep=2, fingerprint=cfg.name)
    monitor = StepMonitor()

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_init(ocfg, params)
    step = jnp.zeros((), jnp.int32)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start = manifest["step"]
        pipe.load_state_dict(manifest["extra"]["pipeline"])
        step = jnp.asarray(start, jnp.int32)
        print(f"restored from checkpoint at step {start}")

    for i in range(start, args.steps):
        if i == args.inject_failure_at:
            print("injected failure! restart this script to observe recovery.")
            raise SystemExit(17)
        b = pipe.next()
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.perf_counter()
        params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
        loss = float(metrics["loss"])
        slow = monitor.observe(i, time.perf_counter() - t0)
        if slow:
            print(f"step {i}: straggler detected -> early checkpoint")
            mgr.save(i, (params, opt_state), extra={"pipeline": pipe.state_dict()})
        if i % args.ckpt_every == 0 and i > start:
            mgr.save_async(i, (params, opt_state), extra={"pipeline": pipe.state_dict()})
        if i % 20 == 0:
            print(f"step {i:4d} loss {loss:.4f} ({monitor.median*1e3:.0f} ms/step)")
    mgr.wait()
    mgr.save(args.steps, (params, opt_state), extra={"pipeline": pipe.state_dict()})
    print(f"done: final loss {loss:.4f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
