"""Batched multi-instance sampling service (repro.serve).

CI-blocking contracts:

- fusing is invisible: fused multi-request results are bit-identical to
  per-request ``random_walk`` calls at the same padded geometry, on both
  backends, and to the service's own one-launch-per-request mode;
- padding-bucket cohorts never mix lowered transition programs (mixed-spec
  requests cannot share a compiled trace);
- admission control rejects malformed and over-capacity requests;
- partitioned services route through the §V frontier-queue drain with
  per-request depth limits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.engine import random_walk, random_walk_segments
from repro.core.oom import oom_random_walk
from repro.graph import powerlaw_graph
from repro.graph.partition import partition_by_vertex_range
from repro.serve import (
    AdmissionError,
    DrainError,
    RequestQueue,
    SamplingRequest,
    SamplingService,
    ServiceConfig,
    cohort_key,
)


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(2000, exponent=2.1, seed=3, weighted=True)


def _mixed_requests(svc, g, n_requests=9, seed=11):
    """Submit a heterogeneous burst; returns {rid: (seeds, depth, spec)}."""
    rng = np.random.default_rng(seed)
    specs = [alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()]
    subs = {}
    for i in range(n_requests):
        spec = specs[i % len(specs)]
        seeds = rng.integers(0, g.num_vertices, int(rng.integers(4, 40)))
        depth = int(rng.integers(2, 12))
        rid = svc.submit(seeds, depth=depth, spec=spec)
        subs[rid] = (seeds, depth, spec)
    return subs


def _assert_walks_valid(g, walks):
    ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
    for row in np.asarray(walks):
        for a, b in zip(row[:-1], row[1:]):
            if a < 0 or b < 0:
                break
            assert b in ind[ip[a] : ip[a + 1]], (a, b)


def _req(rid, n, depth, spec, key=0):
    return SamplingRequest(
        request_id=rid,
        seeds=np.zeros(n, np.int32),
        depth=depth,
        spec=spec,
        key=jax.random.PRNGKey(key),
    )


class TestRequestQueue:
    def test_admission_rejects_malformed(self):
        q = RequestQueue(ServiceConfig(max_walkers_per_request=64, max_depth=16))
        with pytest.raises(AdmissionError):  # empty seeds
            q.submit(_req(0, 0, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError):  # oversized request
            q.submit(_req(1, 65, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError):  # depth out of range
            q.submit(_req(2, 4, 17, alg.deepwalk()))
        with pytest.raises(AdmissionError):  # zero depth
            q.submit(_req(3, 4, 0, alg.deepwalk()))
        assert len(q) == 0

    def test_admission_backpressure(self):
        q = RequestQueue(ServiceConfig(max_pending_requests=2))
        q.submit(_req(0, 4, 4, alg.deepwalk()))
        q.submit(_req(1, 4, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError):
            q.submit(_req(2, 4, 4, alg.deepwalk()))
        qw = RequestQueue(ServiceConfig(max_pending_walkers=10))
        qw.submit(_req(0, 8, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError):
            qw.submit(_req(1, 8, 4, alg.deepwalk()))
        # draining frees capacity
        qw.take_cohorts()
        qw.submit(_req(1, 8, 4, alg.deepwalk()))
        assert qw.pending_walkers == 8

    def test_cohorts_never_mix_programs(self):
        """Padding-bucket batching: mixed-spec requests never share a trace
        across different lowered programs."""
        q = RequestQueue(ServiceConfig())
        reqs = [
            _req(0, 8, 4, alg.deepwalk()),
            _req(1, 8, 4, alg.weighted_random_walk()),
            _req(2, 8, 4, alg.node2vec()),
            _req(3, 8, 4, alg.deepwalk()),
            _req(4, 8, 4, alg.metropolis_hastings_walk()),
        ]
        for r in reqs:
            q.submit(r)
        cohorts = q.take_cohorts()
        for c in cohorts:
            keys = {cohort_key(r.spec) for r in c.requests}
            assert len(keys) == 1 and next(iter(keys)) == c.key
        # the two deepwalk requests DO fuse; the rest are singletons
        sizes = sorted(len(c.requests) for c in cohorts)
        assert sizes == [1, 1, 1, 2]

    def test_equal_programs_from_separate_factory_calls_fuse(self):
        # module-level flat-bias hooks => equal lowered programs
        assert cohort_key(alg.deepwalk()) == cohort_key(alg.deepwalk())
        # node2vec closes its hook per call => distinct programs, no fusion
        assert cohort_key(alg.node2vec()) != cohort_key(alg.node2vec())
        n2v = alg.node2vec()
        assert cohort_key(n2v) == cohort_key(n2v)

    def test_shape_buckets_split_and_pad(self):
        q = RequestQueue(ServiceConfig(min_walker_bucket=8, min_depth_bucket=4))
        q.submit(_req(0, 5, 3, alg.deepwalk()))  # -> (8, 4)
        q.submit(_req(1, 8, 4, alg.deepwalk()))  # -> (8, 4) fuses with 0
        q.submit(_req(2, 9, 4, alg.deepwalk()))  # width 16: separate cohort
        q.submit(_req(3, 8, 5, alg.deepwalk()))  # depth 8: separate cohort
        cohorts = q.take_cohorts()
        geo = sorted((c.width, c.depth, len(c.requests)) for c in cohorts)
        assert geo == [(8, 4, 2), (8, 8, 1), (16, 4, 1)]

    def test_max_requests_per_launch_splits(self):
        q = RequestQueue(ServiceConfig(max_requests_per_launch=4))
        for i in range(10):
            q.submit(_req(i, 8, 4, alg.deepwalk()))
        sizes = sorted(len(c.requests) for c in q.take_cohorts())
        assert sizes == [2, 4, 4]

    def test_oom_grouping_merges_depths(self):
        q = RequestQueue(ServiceConfig())
        q.submit(_req(0, 8, 3, alg.deepwalk()))
        q.submit(_req(1, 40, 11, alg.deepwalk()))
        (c,) = q.take_cohorts(bucket_by_shape=False)
        assert len(c.requests) == 2 and c.depth >= 11

    def test_admission_errors_name_violated_limits(self):
        """Every limit rejection names the limit and its configured value —
        operators must be able to tell back-pressure from misconfiguration
        without string-guessing."""
        q = RequestQueue(ServiceConfig(
            max_walkers_per_request=64, max_depth=16,
            max_pending_requests=1, max_pending_walkers=10,
        ))
        with pytest.raises(AdmissionError, match="max_walkers_per_request=64"):
            q.submit(_req(0, 65, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError, match="max_depth=16"):
            q.submit(_req(1, 4, 17, alg.deepwalk()))
        q.submit(_req(2, 4, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError, match="max_pending_requests=1"):
            q.submit(_req(3, 4, 4, alg.deepwalk()))
        qw = RequestQueue(ServiceConfig(max_pending_walkers=10))
        qw.submit(_req(0, 8, 4, alg.deepwalk()))
        with pytest.raises(AdmissionError, match="max_pending_walkers=10"):
            qw.submit(_req(1, 8, 4, alg.deepwalk()))

    def test_take_cohorts_ordering_contract(self):
        """The documented FIFO-fair ordering: members in submission order
        within a cohort, cohorts by earliest member submission across keys,
        and the whole thing a pure function of the submission sequence."""
        def feed(q):
            q.submit(_req(0, 8, 4, alg.deepwalk()))
            q.submit(_req(1, 8, 4, alg.weighted_random_walk()))
            q.submit(_req(2, 8, 4, alg.deepwalk()))
            q.submit(_req(3, 40, 4, alg.deepwalk()))  # width 64: own cohort
            q.submit(_req(4, 8, 4, alg.weighted_random_walk()))
            q.submit(_req(5, 8, 4, alg.deepwalk()))
            return [[r.request_id for r in c.requests] for c in q.take_cohorts()]

        got = feed(RequestQueue(ServiceConfig()))
        # members in submission order; groups by earliest member submission
        assert got == [[0, 2, 5], [1, 4], [3]]
        # deterministic: an identically-fed queue produces the identical list
        assert feed(RequestQueue(ServiceConfig())) == got

    def test_take_cohorts_split_groups_stay_in_member_order(self):
        q = RequestQueue(ServiceConfig(max_requests_per_launch=2))
        for i in range(5):
            q.submit(_req(i, 8, 4, alg.deepwalk()))
        got = [[r.request_id for r in c.requests] for c in q.take_cohorts()]
        assert got == [[0, 1], [2, 3], [4]]


class TestFusedParity:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_fused_matches_per_request_engine_calls(self, graph, backend):
        """Fused multi-request results are bit-identical to standalone
        ``random_walk`` calls at the cohort's padded geometry — the service
        guarantee that batching never changes a request's answer."""
        g = graph
        svc = SamplingService(g, backend=backend)
        rng = np.random.default_rng(11)
        specs = [alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()]
        subs = {}
        for i in range(6):
            spec = specs[i % len(specs)]
            seeds = rng.integers(0, g.num_vertices, int(rng.integers(4, 40)))
            depth = int(rng.integers(2, 12))
            key = jax.random.fold_in(jax.random.PRNGKey(42), i)
            rid = svc.submit(seeds, depth=depth, spec=spec, key=key)
            subs[rid] = (seeds, depth, spec, key)
        results = svc.drain()
        assert sorted(results) == sorted(subs)
        from repro.serve.queue import _pow2_bucket

        cfg = svc.config
        for rid, (seeds, depth, spec, key) in subs.items():
            width = _pow2_bucket(len(seeds), cfg.min_walker_bucket)
            depth_b = _pow2_bucket(depth, cfg.min_depth_bucket)
            row = np.full((width,), -1, np.int32)
            row[: len(seeds)] = seeds
            solo = random_walk(
                g, jnp.asarray(row), key, depth=depth_b, spec=spec,
                max_degree=g.max_degree(), backend=backend,
            )
            expect = np.asarray(solo.walks)[: len(seeds), : depth + 1]
            np.testing.assert_array_equal(results[rid].walks, expect)

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_fused_matches_unfused_service(self, graph, backend):
        g = graph
        runs = []
        for fuse in (True, False):
            svc = SamplingService(
                g, backend=backend, key=jax.random.PRNGKey(5),
                config=ServiceConfig(fuse=fuse),
            )
            _mixed_requests(svc, g, n_requests=6)
            runs.append(svc.drain())
        fused, seq = runs
        assert sorted(fused) == sorted(seq)
        for rid in fused:
            np.testing.assert_array_equal(fused[rid].walks, seq[rid].walks)
            np.testing.assert_array_equal(fused[rid].lengths, seq[rid].lengths)
            assert fused[rid].sampled_edges == seq[rid].sampled_edges

    def test_fused_uses_fewer_launches(self, graph):
        g = graph
        svc = SamplingService(g, backend="reference")
        rng = np.random.default_rng(0)
        for _ in range(8):  # homogeneous: all 8 fuse into one launch
            svc.submit(rng.integers(0, g.num_vertices, 16), depth=4, spec=alg.deepwalk())
        svc.drain()
        assert svc.stats.requests_served == 8
        assert svc.stats.launches == 1

    def test_results_are_valid_walks(self, graph):
        g = graph
        svc = SamplingService(g, backend="reference")
        subs = _mixed_requests(svc, g, n_requests=5)
        results = svc.drain()
        for rid, (seeds, depth, _) in subs.items():
            r = results[rid]
            assert r.walks.shape == (len(seeds), depth + 1)
            np.testing.assert_array_equal(r.walks[:, 0], seeds.astype(np.int32))
            assert int(r.lengths.max()) <= depth + 1
            _assert_walks_valid(g, r.walks)


class TestSegmentsEngine:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_rows_match_standalone(self, graph, backend):
        g = graph
        keys = jax.vmap(jax.random.fold_in, (None, 0))(
            jax.random.PRNGKey(2), jnp.arange(3)
        )
        seeds = jax.random.randint(jax.random.PRNGKey(3), (3, 16), 0, g.num_vertices)
        fused = random_walk_segments(
            g, seeds, keys, depth=5, spec=alg.node2vec(),
            max_degree=g.max_degree(), backend=backend,
        )
        assert fused.walks.shape == (3, 16, 6)
        for r in range(3):
            solo = random_walk(
                g, seeds[r], keys[r], depth=5, spec=alg.node2vec(),
                max_degree=g.max_degree(), backend=backend,
            )
            np.testing.assert_array_equal(fused.walks[r], solo.walks)
            assert int(fused.sampled_edges[r]) == int(solo.sampled_edges)


class TestOOMService:
    def test_oom_routed_requests(self, graph):
        """Partitioned service: heterogeneous requests merge into one
        frontier-queue drain; every walk is a real path that stops at its
        own request's depth."""
        g = graph
        parts = partition_by_vertex_range(g, 4)
        svc = SamplingService(
            partitions=parts, total_vertices=g.num_vertices,
            backend="reference", oom_chunk=128,
        )
        rng = np.random.default_rng(1)
        a = svc.submit(rng.integers(0, g.num_vertices, 30), depth=4, spec=alg.deepwalk())
        b = svc.submit(rng.integers(0, g.num_vertices, 20), depth=9, spec=alg.deepwalk())
        c = svc.submit(rng.integers(0, g.num_vertices, 10), depth=9, spec=alg.node2vec())
        results = svc.drain()
        # deepwalk requests with different depths share ONE scheduler pass
        assert svc.stats.oom_launches == 2
        for rid, depth in ((a, 4), (b, 9), (c, 9)):
            r = results[rid]
            assert r.walks.shape[1] == depth + 1
            _assert_walks_valid(g, r.walks)
        # power-law graphs at this size have no dead ends on these seeds'
        # giant component for most walkers: depths must be respected exactly
        assert int(results[a].lengths.max()) <= 5
        assert int(results[b].lengths.max()) == 10

    def test_oom_depth_limits_direct(self, graph):
        g = graph
        parts = partition_by_vertex_range(g, 4)
        seeds = np.random.default_rng(0).integers(0, g.num_vertices, 48)
        limits = np.random.default_rng(1).integers(1, 8, 48)
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=8,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=128,
            backend="reference", depth_limits=limits,
        )
        lengths = (walks >= 0).sum(axis=1)
        assert (lengths <= limits + 1).all()

    def test_service_seed_range_admission(self, graph):
        g = graph
        svc = SamplingService(g)
        with pytest.raises(AdmissionError):
            svc.submit([g.num_vertices], depth=4, spec=alg.deepwalk())
        with pytest.raises(AdmissionError):
            svc.submit([-1], depth=4, spec=alg.deepwalk())

    def test_oom_depth_limits_range_validated(self, graph):
        g = graph
        parts = partition_by_vertex_range(g, 4)
        seeds = np.arange(8)
        with pytest.raises(ValueError):
            oom_random_walk(
                parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=4,
                spec=alg.deepwalk(), max_degree=g.max_degree(),
                backend="reference", depth_limits=np.full(8, 9),
            )


class TestPrewarm:
    """prewarm() across placements: warms plans and launch traces without
    perturbing serving semantics (ids, keys, results, benchmark counters)."""

    def _drain_one(self, svc, g, n=12, depth=6):
        rid = svc.submit(np.arange(n) % g.num_vertices, depth=depth,
                         spec=alg.deepwalk())
        return svc.drain()[rid]

    def test_memory_prewarm_records_placement_and_stays_invisible(self, graph):
        g = graph
        cold = SamplingService(g, backend="reference", key=jax.random.PRNGKey(4))
        warm = SamplingService(g, backend="reference", key=jax.random.PRNGKey(4))
        warm.prewarm(alg.deepwalk(), depth=6, width=12)
        warm.prewarm(alg.deepwalk(), depth=6, width=12)  # idempotent
        assert warm.stats.prewarmed_placements == ("memory",)
        assert warm.stats.launches == 0  # ghost launches aren't counted
        np.testing.assert_array_equal(
            self._drain_one(warm, g).walks, self._drain_one(cold, g).walks
        )

    def test_partitioned_prewarm(self, graph):
        g = graph
        parts = partition_by_vertex_range(g, 4)
        mk = lambda: SamplingService(
            partitions=parts, total_vertices=g.num_vertices,
            backend="reference", oom_chunk=128, key=jax.random.PRNGKey(4),
        )
        cold, warm = mk(), mk()
        warm.prewarm(alg.deepwalk(), depth=6, width=12)
        assert warm.stats.prewarmed_placements == ("oom",)
        # no launch-key consumed: the first real drain samples identically
        np.testing.assert_array_equal(
            self._drain_one(warm, g).walks, self._drain_one(cold, g).walks
        )
        assert warm.stats.oom_launches == 1  # only the real drain counted

    def test_sharded_prewarm(self, graph):
        g = graph
        mesh = jax.make_mesh((1,), ("data",))
        mk = lambda: SamplingService(
            g, mesh=mesh, placement="sharded", backend="reference",
            key=jax.random.PRNGKey(4),
        )
        cold, warm = mk(), mk()
        warm.prewarm(alg.deepwalk(), depth=6, width=12)
        assert warm.stats.prewarmed_placements == ("sharded",)
        assert warm.stats.plans_prewarmed == 1  # reuses the full-graph plan
        np.testing.assert_array_equal(
            self._drain_one(warm, g).walks, self._drain_one(cold, g).walks
        )
        assert warm.stats.sharded_launches == 1


class TestRobustness:
    def test_submit_copies_seeds(self, graph):
        """Mutating the caller's array after submit must not bypass the
        admission-time range check."""
        g = graph
        svc = SamplingService(g, backend="reference")
        a = np.zeros(8, np.int32)
        rid = svc.submit(a, depth=4, spec=alg.deepwalk())
        a[:] = 10**9
        res = svc.drain()[rid]
        np.testing.assert_array_equal(res.walks[:, 0], np.zeros(8, np.int32))

    def test_drain_failure_requeues_and_keeps_completed(self, graph, monkeypatch):
        """A failing cohort launch loses nothing: completed results ride the
        DrainError, unserved requests are re-queued and retryable."""
        g = graph
        svc = SamplingService(g, backend="reference")
        a = svc.submit([0, 1], depth=4, spec=alg.deepwalk())
        b = svc.submit([2, 3], depth=4, spec=alg.node2vec())  # separate cohort
        import repro.serve.service as service_mod

        real = service_mod.random_walk_segments
        calls = {"n": 0}

        def flaky(*args, **kwargs):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("injected launch failure")
            return real(*args, **kwargs)

        monkeypatch.setattr(service_mod, "random_walk_segments", flaky)
        with pytest.raises(DrainError) as ei:
            svc.drain()
        completed = ei.value.completed
        assert len(completed) == 1
        assert svc.pending == 1  # the failed cohort's request is back
        retry = svc.drain()  # third call succeeds
        served = {**completed, **retry}
        assert sorted(served) == sorted([a, b])
        for rid in (a, b):
            assert served[rid].walks.shape == (2, 5)
