"""Transition programs: lowering the three-hook API onto the fast path.

C-SAW's user API is three opaque callables (vertex bias, edge bias, update —
``core.api``).  Opaque hooks force the engines onto the dense full-context
gather: every step materializes ``(W, max_degree)`` neighbor/weight/degree
tensors just to evaluate a bias that is usually one of a handful of shapes.
This module closes that gap with a small declarative IR — the **transition
program** — that names what a spec's hooks actually consume, so the backend
can compile the step instead of interpreting it:

Bias sources (where the per-edge transition bias comes from):

  - :class:`FlatBias`    — a static ``(E,)`` CSR-order array (deepwalk,
    weighted/biased walks).  Sampled straight off the flat edge arrays by the
    degree-bucketed scheduler; no neighbor tensors ever exist.
  - :class:`WindowBias`  — a dynamic function of the walker's *gathered
    neighbor window* and carried state (prev vertex): node2vec and friends.
    Evaluated per degree bucket on the kernel's block-aligned edge windows
    (``(W, 2·seg)`` per cohort), never on a dense ``max_degree`` gather.
  - :class:`OpaqueBias`  — anything else; the dense gather survives only as
    this fallback.

Epilogues (what happens after the ITS draw picks neighbor ``u``):

  - :class:`IdentityEpilogue` — walk to ``u``.
  - :class:`MHAcceptEpilogue` — Metropolis-Hastings: accept ``u`` w.p.
    ``min(1, deg(v)/deg(u))``, else stay at ``v``.
  - :class:`TeleportEpilogue` — with probability ``prob`` go elsewhere:
    a uniform random vertex (jump), a fixed vertex (restart), or the
    walk's own seed (``"home"`` restart).
  - :class:`OpaqueEpilogue`   — defer to ``spec.update`` (full generality).

All epilogues lower to one fused post-select jnp step
(:func:`apply_epilogue`) shared by ``engine.random_walk``,
``engine.traversal_sample`` and the ``oom`` drain loop, and consume the same
counted RNG on every backend, so reference and Pallas walks stay
bit-identical.

State carried across steps is part of the program: the previous vertex is
always threaded through the engines' scan carries (every bias may read it),
``carries_home`` (teleport-to-seed) tells them to also thread the
per-instance home vertex; the per-instance RNG budget is the counted-RNG
contract the backends already share (``select.retry_randoms``).

Specs *declare* their program (``SamplingSpec.transition``); legacy specs
without a declaration are inferred by :func:`lower` from the PR-1 era flags
(``flat_edge_bias`` ⇒ flat, else opaque) so external code keeps working.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.api import EdgeCtx, SamplingSpec, identity_update

# ---------------------------------------------------------------------------
# Bias sources
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatBias:
    """Static per-edge bias: ``fn(graph) -> (E,)`` float32 in CSR order.

    Must satisfy ``fn(g)[e] == spec.edge_bias(ctx)`` for every real edge
    ``e`` (the PR-1 ``flat_edge_bias`` contract).
    """

    fn: Callable[[object], jax.Array]


@dataclasses.dataclass(frozen=True)
class WindowBias:
    """Dynamic per-edge bias evaluated on gathered neighbor windows.

    ``fn`` is an :class:`~repro.core.api.EdgeBiasFn` — it receives an
    ``EdgeCtx`` whose neighbor axis is a degree-bucket *window* (block-aligned
    ``(W, 2·seg)`` slices of the CSR edge arrays, or ``(W, chunk)`` slices on
    the huge-degree two-pass tail) instead of a dense ``(W, max_degree)``
    gather.  The bias of each candidate must depend only on per-edge context
    (``u``, ``weight``, ``deg_u``, ``is_prev_neighbor``) and per-walker state
    (``v``, ``prev``, ``deg_v``, ``depth``) — i.e. it must be *rankable
    per-window*, which every EDGEBIAS of the paper's Table I is.

    The previous vertex is always available (the walk engines carry it for
    every spec); ``needs_prev_neighbors`` requests the ``is_prev_neighbor``
    field — on the windowed path membership is a per-candidate binary search
    over ``prev``'s sorted CSR row (O(D·log deg) instead of the dense path's
    O(D²) compare).  ``needs_deg_u=False`` declares the hook never reads
    ``ctx.deg_u`` and skips two window-wide degree gathers per cohort (it
    reads as zeros).
    """

    fn: Callable[[EdgeCtx], jax.Array]
    needs_prev_neighbors: bool = False
    needs_deg_u: bool = True


@dataclasses.dataclass(frozen=True)
class OpaqueBias:
    """Fallback: evaluate ``spec.edge_bias`` on the dense full-context gather."""


BiasSource = Union[FlatBias, WindowBias, OpaqueBias]


# ---------------------------------------------------------------------------
# Epilogues
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IdentityEpilogue:
    """Walk to the selected neighbor."""


@dataclasses.dataclass(frozen=True)
class MHAcceptEpilogue:
    """Metropolis-Hastings acceptance: keep ``u`` w.p. ``min(1, deg_v/deg_u)``,
    else stay at ``v`` (paper Table I, MHRW)."""


@dataclasses.dataclass(frozen=True)
class TeleportEpilogue:
    """With probability ``prob`` replace ``u`` by a teleport target.

    target="uniform": a uniform random vertex in ``[0, num_vertices)`` (jump);
    target="fixed":   the predetermined ``vertex`` (restart);
    target="home":    the walk's own seed vertex (restart-to-home) — engines
                      thread the per-instance home array through their carry.
    """

    prob: float
    target: Literal["uniform", "fixed", "home"] = "uniform"
    vertex: int = -1
    num_vertices: int = 0

    def __post_init__(self):
        if self.target == "uniform" and self.num_vertices <= 0:
            raise ValueError(
                "TeleportEpilogue(target='uniform') needs num_vertices > 0 "
                "(randint over an empty range would silently teleport every "
                "jumper to vertex 0)"
            )
        if self.target == "fixed" and self.vertex < 0:
            raise ValueError("TeleportEpilogue(target='fixed') needs vertex >= 0")


@dataclasses.dataclass(frozen=True)
class OpaqueEpilogue:
    """Fallback: call ``spec.update`` (arbitrary user code)."""


Epilogue = Union[IdentityEpilogue, MHAcceptEpilogue, TeleportEpilogue, OpaqueEpilogue]


# ---------------------------------------------------------------------------
# The program
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransitionProgram:
    """One walk step, declaratively: bias source + carried state + epilogue.

    Frozen/hashable so it rides inside ``SamplingSpec`` as a jit static
    argument, exactly like the hook fields it lowers.
    """

    bias: BiasSource
    epilogue: Epilogue = IdentityEpilogue()
    #: Selection method for the per-degree-bucket scheduler (DESIGN.md §13):
    #: ``"auto"`` lets the cost model pick per bucket (static FlatBias →
    #: alias tables, near-uniform bias → rejection, dynamic WindowBias →
    #: ITS); ``"its"``/``"alias"``/``"rejection"`` force one method for
    #: every bucket.  Only the flat-bias fast path consults it — window and
    #: opaque modes are inherently dynamic and always use ITS.
    method: str = "auto"

    def __post_init__(self):
        if self.method not in ("auto", "its", "alias", "rejection"):
            raise ValueError(
                f"unknown selection method {self.method!r}; expected one of "
                "'auto', 'its', 'alias', 'rejection'"
            )

    @property
    def carries_home(self) -> bool:
        return (
            isinstance(self.epilogue, TeleportEpilogue)
            and self.epilogue.target == "home"
        )

    @property
    def mode(self) -> str:
        """Engine dispatch mode: ``"flat"`` / ``"window"`` run the
        degree-bucketed fast path, ``"opaque"`` the dense-gather fallback."""
        if isinstance(self.bias, FlatBias):
            return "flat"
        if isinstance(self.bias, WindowBias):
            return "window"
        return "opaque"


def lower(spec: SamplingSpec) -> TransitionProgram:
    """Compile a spec's hooks into a transition program.

    A declared ``spec.transition`` wins.  Otherwise the legacy flags are
    lowered: ``flat_edge_bias`` ⇒ :class:`FlatBias` (the PR-1 fast-path
    contract), anything else ⇒ :class:`OpaqueBias`; an ``update`` other than
    ``identity_update`` ⇒ :class:`OpaqueEpilogue`.  Inference cannot prove a
    hook windowable — only declarations reach the :class:`WindowBias` path.
    """
    override = getattr(spec, "selection_method", None)
    if spec.transition is not None:
        prog = spec.transition
        if override is not None and override != prog.method:
            prog = dataclasses.replace(prog, method=override)
        return prog
    if spec.flat_edge_bias is not None and not spec.needs_prev_neighbors:
        bias: BiasSource = FlatBias(spec.flat_edge_bias)
    else:
        bias = OpaqueBias()
    epi: Epilogue = (
        IdentityEpilogue() if spec.update is identity_update else OpaqueEpilogue()
    )
    return TransitionProgram(bias=bias, epilogue=epi, method=override or "auto")


# ---------------------------------------------------------------------------
# The fused post-select epilogue
# ---------------------------------------------------------------------------


def apply_epilogue(
    key: jax.Array,
    program: TransitionProgram,
    spec: SamplingSpec,
    ctx: EdgeCtx,
    u: jax.Array,
    home: Optional[jax.Array] = None,
) -> jax.Array:
    """Lowered UPDATE: one fused jnp step shared by every engine.

    ``ctx`` is the (possibly minimal, D=1) EdgeCtx of the selected edge and
    ``u`` the selected neighbor (same shape as ``ctx.v``; -1 for dead
    walkers — epilogues must preserve -1).  ``home`` is the per-instance home
    vertex array, required iff ``program.carries_home``.  RNG: exactly one
    ``key`` per step, consumed identically on every backend.
    """
    epi = program.epilogue
    if isinstance(epi, IdentityEpilogue):
        return u
    if isinstance(epi, MHAcceptEpilogue):
        deg_u = _selected_deg_u(ctx, u)
        stay = mh_stay(jax.random.uniform(key, u.shape), ctx.deg_v, deg_u)
        return jnp.where(stay & (ctx.v >= 0) & (u >= 0), ctx.v, u)
    if isinstance(epi, TeleportEpilogue):
        kj, kv = jax.random.split(key)
        teleport = jax.random.uniform(kj, u.shape) < epi.prob
        if epi.target == "uniform":
            tgt = jax.random.randint(kv, u.shape, 0, epi.num_vertices)
        elif epi.target == "fixed":
            tgt = jnp.full_like(u, epi.vertex)
        else:  # "home"
            if home is None:
                raise ValueError(
                    "TeleportEpilogue(target='home') needs the per-instance "
                    "home array; this engine does not carry one"
                )
            tgt = jnp.broadcast_to(jnp.expand_dims(home, tuple(range(home.ndim, u.ndim))), u.shape)
        return jnp.where(teleport & (u >= 0), tgt, u)
    # OpaqueEpilogue — full generality through the user hook
    return spec.update(key, ctx, u)


def mh_stay(r: jax.Array, deg_v: jax.Array, deg_u: jax.Array) -> jax.Array:
    """The MH acceptance test, in one place: stay iff ``r >= min(1,
    deg_v/deg_u)`` (paper Table I, MHRW).

    ``deg_v``/``deg_u`` are int32 true degrees; the division promotes to
    float32 exactly like the engine's fused epilogue, so every caller —
    ``apply_epilogue`` here, the owner-routed sharded drain
    (``shard/walk.py``, which resolves ``deg_u`` from its replicated hub /
    resident-row degree lanes) — decides acceptance with bit-identical
    arithmetic from the same counted uniform.
    """
    accept_p = jnp.minimum(1.0, deg_v / jnp.maximum(deg_u, 1))
    return r >= accept_p


def _selected_deg_u(ctx: EdgeCtx, u: jax.Array) -> jax.Array:
    """deg(u) for the selected neighbor, from whatever ctx the path built.

    Fast paths hand a minimal D=1 ctx (``ctx.u == u[..., None]``); the dense
    path hands the full window — locate ``u`` in it (the same arithmetic the
    legacy MHRW hook used).
    """
    if ctx.u.shape[-1] == 1:
        return ctx.deg_u[..., 0]
    pos = jnp.argmax(ctx.u == u[..., None], axis=-1)
    return jnp.where(
        u >= 0,
        jnp.take_along_axis(ctx.deg_u, pos[..., None], axis=-1)[..., 0],
        1,
    )
