"""C-SAW sampling engines (paper Fig. 2(b) MAIN loop, §IV).

Two drivers, both batched over thousands of concurrent instances
(the paper's inter-warp parallelism; here: leading array dims):

  - ``random_walk``       — NeighborSize=1 path-per-instance (Table I left).
  - ``traversal_sample``  — frontier-pool sampling (neighbor / layer /
                            forest-fire / snowball / MDRW).

Both are jit-compiled, use counted RNG, fixed shapes, masked semantics, and
route all bias-based selection through the backend dispatcher
(``core.backend``), so they run unchanged under vmap / shard_map / the
partition scheduler.  Walk steps dispatch on the spec's lowered transition
program (``core.transition``, DESIGN.md §10): flat- and window-bias
programs run the degree-bucketed scheduler on BOTH backends —
``backend="pallas"`` swaps in the fused Pallas kernels, ``"reference"``
their bit-identical pure-jnp mirrors — and declarative epilogues fuse into
one shared post-select step; only opaque programs keep the dense gather.
``"auto"`` picks per device (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.api import EdgeCtx, SamplingSpec, VertexCtx
from repro.core import backend as bk
from repro.core import methods as mt
from repro.core import select as sel
from repro.core import transition as tp
from repro.graph.csr import CSRGraph, neighbors_padded


def _degree(graph: CSRGraph, v: jax.Array) -> jax.Array:
    safe = jnp.maximum(v, 0)
    return jnp.where(v >= 0, graph.indptr[safe + 1] - graph.indptr[safe], 0)


def _edge_ctx(graph: CSRGraph, v, prev, depth, max_degree, needs_prev_neighbors,
              *, partition=None):
    """Build the EDGEBIAS context for a batch of frontier vertices.

    With ``partition`` (a ``graph.partition.DevicePartition``) set, ``graph``
    is its compact local-id CSR with a phantom sink row (DESIGN.md §8): row
    lookups happen on localized ids while the context exposes global ids;
    neighbors outside the partition localize to the phantom row, so their
    ``deg_u`` is 0 — the §V semantics where only partition-resident edge
    data informs the bias.
    """
    local = partition is not None
    if local:
        indices_global = partition.indices_global
        vq, pq = partition.localize(v), partition.localize(prev)
    else:
        vq, pq = jnp.maximum(v, 0), jnp.maximum(prev, 0)
    nbrs, wts, mask = neighbors_padded(graph, vq, max_degree)
    nbrs_row = nbrs  # row-lookup ids (local in partition mode)
    if local:
        eidx = graph.indptr[vq][..., None] + jnp.arange(max_degree, dtype=jnp.int32)
        nbrs = jnp.where(mask, indices_global[jnp.where(mask, eidx, 0)], -1)
    nbrs = jnp.where((v >= 0)[..., None] & mask, nbrs, -1)
    mask = nbrs >= 0
    ipn = None
    if needs_prev_neighbors:
        if local:
            _, _, pmask = neighbors_padded(graph, pq, max_degree)
            peidx = graph.indptr[pq][..., None] + jnp.arange(max_degree, dtype=jnp.int32)
            pnbrs = jnp.where(pmask, indices_global[jnp.where(pmask, peidx, 0)], -2)
        else:
            pnbrs, _, pmask = neighbors_padded(graph, pq, max_degree)
        pnbrs = jnp.where((prev >= 0)[..., None] & pmask & (pnbrs >= 0), pnbrs, -2)
        # membership: u in N(prev) — O(D^2) lane-parallel compare (global ids)
        ipn = jnp.any(nbrs[..., :, None] == pnbrs[..., None, :], axis=-1) & mask
    deg_u = _degree(graph, nbrs_row) if local else _degree(graph, nbrs)
    return (
        EdgeCtx(
            v=v,
            u=nbrs,
            weight=wts,
            deg_v=_degree(graph, vq if local else v),
            deg_u=jnp.where(mask, deg_u, 0),
            prev=prev,
            is_prev_neighbor=ipn,
            depth=depth,
        ),
        mask,
    )


def _select_epilogue(key, graph, program, spec, v, prev, depth, u, vq, row_of, home):
    """Fused post-select step shared by the flat and window fast paths:
    build the minimal D=1 EdgeCtx of the selected edge and run the lowered
    epilogue (``transition.apply_epilogue`` — identity/MH/teleport fuse into
    a few jnp ops; opaque falls back to ``spec.update``).  The minimal ctx
    carries a unit placeholder ``weight`` (fast-path contract,
    api.flat_edge_bias)."""
    alive = u >= 0
    ctx = EdgeCtx(
        v=v,
        u=u[..., None],
        weight=jnp.ones(u.shape + (1,), jnp.float32),
        deg_v=_degree(graph, vq),
        deg_u=_degree(graph, u if row_of is None else row_of(u))[..., None],
        prev=prev,
        is_prev_neighbor=None,
        depth=depth,
    )
    nxt = tp.apply_epilogue(jax.random.fold_in(key, 2), program, spec, ctx, u, home)
    return jnp.where(alive, nxt, -1)


def walk_flat_transition(key: jax.Array, graph: CSRGraph, indices_out: jax.Array,
                         flat_bias: jax.Array, padded, v: jax.Array, prev: jax.Array,
                         depth, spec: SamplingSpec, be: str, *,
                         buckets: tuple, use_chunked: bool,
                         max_degree: int | None = None, row_of=None,
                         program: tp.TransitionProgram | None = None,
                         home: jax.Array | None = None,
                         methods: tuple | None = None,
                         tables=None) -> jax.Array:
    """SELECT + epilogue of one flat-bias walk step (shared by the in-memory
    engine and the §V out-of-memory drain loop).

    Dispatches the degree-bucketed scheduler (DESIGN.md §6): Pallas kernels
    under ``be="pallas"``, the bit-identical pure-jnp mirror under
    ``"reference"``.  ``row_of`` maps global vertex ids to ``graph``'s
    row-lookup ids (identity in-memory; partition localization in the OOM
    drain); ``indices_out`` holds the ids the walk emits (global).  The
    post-select update runs the spec's lowered transition-program epilogue.

    ``methods``/``tables`` (from ``core.methods.plan_for_graph``) engage the
    adaptive per-bucket selection runtime (DESIGN.md §13); an absent or
    all-ITS plan keeps the legacy kernel/mirror pair — bit-for-bit the
    pre-adaptive walks.
    """
    program = tp.lower(spec) if program is None else program
    vq = v if row_of is None else row_of(v)
    kf = jax.random.fold_in(key, 1)
    if methods is not None and not mt.is_trivial(methods):
        u = bk.walk_step_adaptive(kf, graph.indptr, indices_out, flat_bias,
                                  padded, vq, buckets=buckets,
                                  use_chunked=use_chunked, methods=methods,
                                  tables=tables, backend=be,
                                  max_degree=max_degree)
    elif be == "pallas":
        u = bk.walk_step_bucketed(kf, graph.indptr, indices_out, flat_bias,
                                  padded, vq, buckets=buckets, use_chunked=use_chunked)
    else:
        u = bk.walk_step_flat_reference(kf, graph.indptr, indices_out, flat_bias,
                                        padded, vq, buckets=buckets,
                                        use_chunked=use_chunked, max_degree=max_degree)
    return _select_epilogue(key, graph, program, spec, v, prev, depth, u, vq, row_of, home)


def _is_prev_neighbor_window(indptr, ids_sorted, prow, prev, u, mask, *, steps: int):
    """Membership of window candidates in N(prev): per-candidate lower-bound
    binary search over prev's sorted CSR row (``csr_from_edges`` sorts rows;
    partition localization preserves the order).  O(D·log deg_prev) — the
    windowed replacement for the dense path's O(D²) lane compare — and exact
    for ANY prev degree (the dense path truncates N(prev) at max_degree).

    prow: (W,) row-lookup ids of prev (localized in partition mode);
    u: (W, D) candidate GLOBAL ids; returns (W, D) bool.

    ``steps`` is sized from the caller's max-degree bound.  If that bound is
    understated, the search may not converge on longer prev rows — which can
    only produce false NEGATIVES (``lo`` always lands inside the row, so a
    positive requires a genuine element match): the same truncation-class
    degradation as the dense path's ``neighbors_padded`` cap on N(prev).
    """
    e = ids_sorted.shape[0]
    lo = jnp.broadcast_to(indptr[prow][..., None], u.shape).astype(jnp.int32)
    hi_row = indptr[prow + 1][..., None]
    hi = jnp.broadcast_to(hi_row, u.shape).astype(jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        open_ = lo < hi
        mid = (lo + hi) // 2
        vmid = ids_sorted[jnp.clip(mid, 0, e - 1)]
        go_right = vmid < u
        lo = jnp.where(open_ & go_right, mid + 1, lo)
        hi = jnp.where(open_ & ~go_right, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    found = (lo < hi_row) & (ids_sorted[jnp.clip(lo, 0, e - 1)] == u)
    return found & mask & (prev >= 0)[..., None] & (u >= 0)


def _window_bias_fn(graph: CSRGraph, program: tp.TransitionProgram,
                    v, prev, depth, row_of, ids_sorted,
                    max_degree: int | None = None):
    """Close the spec's dynamic edge-bias hook over the walker state so the
    backend scheduler can evaluate it on any gathered edge window.

    The returned ``bias_of(u, w, mask, eidx=None)`` builds a window
    EdgeCtx — candidate ids/weights straight off the CSR window, degrees by
    row lookup (localized in partition mode, so non-resident neighbors read
    deg 0 off the phantom row, §V semantics), prev-membership by binary
    search — and runs ``WindowBias.fn`` on it.  ``eidx`` (the window's edge
    positions in the caller's CSR edge arrays) is accepted for signature
    compatibility with the sharded drain's carried-state hook
    (``shard.walk._carried_window_bias`` resolves ``deg_u`` through a
    per-edge degree lane instead of row lookups) and ignored here.
    """
    wb = program.bias
    assert isinstance(wb, tp.WindowBias), wb
    vq = v if row_of is None else row_of(v)
    pq = jnp.maximum(prev, 0) if row_of is None else row_of(prev)
    deg_v = _degree(graph, vq)
    # lower-bound halvings: enough for the longest row (``max_degree`` is the
    # true max row degree on this path), else for the whole edge array
    bound = int(ids_sorted.shape[0]) if max_degree is None else max(max_degree, 1)
    bs_steps = min(32, max(1, bound.bit_length()))

    def bias_of(u, w, mask, eidx=None):
        del eidx  # in-memory/OOM: degrees come from row lookups below
        if wb.needs_deg_u:
            uq = u if row_of is None else row_of(u)
            deg_u = jnp.where(mask, _degree(graph, uq), 0)
        else:  # declared unused — skip two window-wide indptr gathers
            deg_u = jnp.zeros(u.shape, jnp.int32)
        ipn = None
        if wb.needs_prev_neighbors:
            ipn = _is_prev_neighbor_window(
                graph.indptr, ids_sorted, pq, prev, u, mask, steps=bs_steps
            )
        ctx = EdgeCtx(
            v=v, u=u, weight=w, deg_v=deg_v, deg_u=deg_u, prev=prev,
            is_prev_neighbor=ipn, depth=depth,
        )
        return wb.fn(ctx)

    return bias_of


def walk_window_transition(key: jax.Array, graph: CSRGraph, indices_out: jax.Array,
                           padded, v: jax.Array, prev: jax.Array,
                           depth, spec: SamplingSpec, program: tp.TransitionProgram,
                           be: str, *, buckets: tuple, use_chunked: bool,
                           max_degree: int | None = None, row_of=None,
                           home: jax.Array | None = None) -> jax.Array:
    """SELECT + epilogue of one window-bias (dynamic) walk step — the
    transition-program path that puts node2vec-class specs on the
    degree-bucketed scheduler (shared by the in-memory engine and the §V
    out-of-memory drain loop).  ``padded`` maps bucket segments to padded
    (ids, WEIGHTS) arrays; the dynamic hook is evaluated per bucket on the
    kernel's gathered windows, chunk-wise on the huge-degree tail."""
    vq = v if row_of is None else row_of(v)
    kf = jax.random.fold_in(key, 1)
    bias_of = _window_bias_fn(
        graph, program, v, prev, depth, row_of, indices_out, max_degree
    )
    u = bk.walk_step_bucketed_window(
        kf, graph.indptr, indices_out, graph.weights, padded, vq, bias_of,
        buckets=buckets, use_chunked=use_chunked, backend=be,
    )
    return _select_epilogue(key, graph, program, spec, v, prev, depth, u, vq, row_of, home)


def walk_gather_transition(key: jax.Array, ctx: EdgeCtx, mask: jax.Array,
                           spec: SamplingSpec, be: str,
                           program: tp.TransitionProgram | None = None,
                           home: jax.Array | None = None) -> jax.Array:
    """SELECT + epilogue of one gather-based walk step — the dense
    full-context fallback for opaque transition programs (shared by the
    in-memory engine and the §V out-of-memory drain loop).

    Dispatches the ITS draw through the backend (bit-identical across
    backends for k=1, DESIGN.md §4/§6); returns next vertices, -1 for dead
    ends and already-finished walkers.
    """
    program = tp.lower(spec) if program is None else program
    biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
    idx = bk.select_with_replacement(
        jax.random.fold_in(key, 1), biases, mask, 1, backend=be
    )[..., 0]
    u = jnp.take_along_axis(ctx.u, idx[..., None], axis=-1)[..., 0]
    alive = (ctx.v >= 0) & jnp.any(mask, axis=-1)
    u = jnp.where(alive, u, -1)
    nxt = tp.apply_epilogue(jax.random.fold_in(key, 2), program, spec, ctx, u, home)
    return jnp.where(alive, nxt, -1)


class WalkResult(NamedTuple):
    walks: jax.Array  # (I, depth+1) int32, -1 after termination
    lengths: jax.Array  # (I,) realized lengths (# vertices)
    sampled_edges: jax.Array  # () total sampled edges (for SEPS)
    #: optional host-side execution counters; only the mesh-sharded walk
    #: fills it (exchange/hub-hit telemetry, DESIGN.md §14) — engines that
    #: construct results inside jit leave the default None (an empty pytree
    #: leaf, so shard_map/vmap out-specs written for the 3-field layout
    #: keep working unchanged)
    stats: Optional[dict] = None


def flat_method_plan(
    graph: CSRGraph,
    program: tp.TransitionProgram,
    max_degree: int,
) -> tuple[tuple, mt.MethodTables]:
    """Host-side adaptive selection plan for a flat-bias program.

    Returns ``(methods, tables)`` for ``walk_flat_transition``: the
    cost-model pick per degree cohort plus the prebuilt tables it needs
    (cached per (graph, bias fn) — ``core.methods``).  Degrades to the
    legacy all-ITS plan when planning is impossible or pointless: non-flat
    programs (empty plan), a forced ``method="its"``, or a TRACED graph
    (``random_walk`` under vmap/make_jaxpr cannot inspect concrete bucket
    stats — those callers keep the pre-adaptive behavior).
    """
    if program.mode != "flat":
        return (), mt.EMPTY_TABLES
    buckets, use_chunked = bk.walk_bucket_plan(max_degree)
    n = len(buckets) + (1 if use_chunked else 0)
    if program.method == "its" or isinstance(graph.indices, jax.core.Tracer):
        return ("its",) * n, mt.EMPTY_TABLES
    override = None if program.method == "auto" else program.method
    return mt.plan_for_graph(
        graph, program.bias.fn, buckets=buckets, use_chunked=use_chunked,
        override=override,
    )


def random_walk(
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    method: str = "its_brs",
    backend: bk.Backend = "auto",
) -> WalkResult:
    """Run one random-walk step per scan iteration for all instances.

    Dispatch is on the spec's lowered transition program (DESIGN.md §10):
    flat-bias programs run the degree-bucketed scheduler straight off the
    flat CSR arrays, window-bias programs (node2vec-class dynamic hooks)
    evaluate their hook per degree bucket on the kernel's gathered edge
    windows — on BOTH backends (Pallas kernels vs the bit-identical jnp
    mirrors), so no padded ``(W, max_degree)`` neighbor tensors are ever
    materialized.  Only opaque programs keep the dense full-context gather,
    still dispatching the ITS draw to the selection kernel.

    Flat-bias programs additionally run the adaptive selection runtime
    (DESIGN.md §13): a host-side cost model picks ITS / alias-table /
    rejection per degree cohort (``TransitionProgram.method`` overrides it)
    and the prebuilt tables are cached per (graph, bias), so repeated
    launches reuse them.

    Seeds may be ``-1``: those instances are dead on arrival and emit all--1
    rows (the padding contract the batched service relies on).

    Example — 4 unbiased walks of 3 steps on a toy 4-cycle:

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import algorithms as alg
    >>> from repro.core.engine import random_walk
    >>> from repro.graph import csr_from_edges
    >>> g = csr_from_edges(4, [0, 1, 2, 3], [1, 2, 3, 0], symmetrize=True)
    >>> res = random_walk(g, jnp.array([0, 1, 2, 3]), jax.random.PRNGKey(0),
    ...                   depth=3, spec=alg.deepwalk(), max_degree=2)
    >>> res.walks.shape, int(res.sampled_edges)
    ((4, 4), 12)
    >>> bool(jnp.all(res.lengths == 4))  # no dead ends on a cycle
    True
    """
    sel_methods, tables = flat_method_plan(graph, tp.lower(spec), max_degree)
    return _random_walk_impl(
        graph, seeds, key, tables, depth=depth, spec=spec,
        max_degree=max_degree, method=method, backend=backend,
        sel_methods=sel_methods,
    )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "spec", "max_degree", "method", "backend", "sel_methods"),
)
def _random_walk_impl(
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    tables: mt.MethodTables,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    method: str = "its_brs",
    backend: bk.Backend = "auto",
    sel_methods: tuple = (),
) -> WalkResult:
    """Jitted body of :func:`random_walk` — the selection plan
    (``sel_methods``, static) and its tables (dynamic pytree; ``None``
    fields cost nothing) arrive precomputed from the host-side wrapper."""
    num_inst = seeds.shape[0]
    be = bk.resolve_backend(backend)
    program = tp.lower(spec)
    mode = program.mode
    if mode == "flat":
        flat_bias = program.bias.fn(graph)
        buckets, use_chunked = bk.walk_bucket_plan(max_degree)
        padded = bk.pad_walk_csr(graph.indices, flat_bias, buckets)
    elif mode == "window":
        # the window path treats max_degree as the TRUE max row degree
        # (exact bucket plan; chunked tail above the top segment)
        buckets, use_chunked = bk.walk_bucket_plan_window(max_degree)
        padded = bk.pad_walk_csr(graph.indices, graph.weights, buckets)
    home = seeds.astype(jnp.int32) if program.carries_home else None

    def step(carry, it):
        cur, prev = carry
        kstep = jax.random.fold_in(key, it)
        if mode == "flat":
            # max_degree stays None: the caller's bound may be understated,
            # and only a TRUE max degree (like the OOM drain computes) may
            # truncate the reference mirror's windows
            nxt = walk_flat_transition(
                kstep, graph, graph.indices, flat_bias, padded, cur, prev, it,
                spec, be, buckets=buckets, use_chunked=use_chunked,
                program=program, home=home, methods=sel_methods or None,
                tables=tables,
            )
        elif mode == "window":
            nxt = walk_window_transition(
                kstep, graph, graph.indices, padded, cur, prev, it, spec,
                program, be, buckets=buckets, use_chunked=use_chunked,
                max_degree=max_degree, home=home,
            )
        else:
            ctx, mask = _edge_ctx(graph, cur, prev, it, max_degree, spec.needs_prev_neighbors)
            nxt = walk_gather_transition(kstep, ctx, mask, spec, be, program, home)
        return (nxt, cur), nxt

    (_, _), path = jax.lax.scan(step, (seeds.astype(jnp.int32), jnp.full((num_inst,), -1, jnp.int32)), jnp.arange(depth))
    walks = jnp.concatenate([seeds[None].astype(jnp.int32), path], axis=0).T  # (I, depth+1)
    lengths = jnp.sum(walks >= 0, axis=-1)
    return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))


def random_walk_segments(
    graph: CSRGraph,
    seeds: jax.Array,
    keys: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    method: str = "its_brs",
    backend: bk.Backend = "auto",
) -> WalkResult:
    """Multi-request segment path: R independent requests, ONE device launch.

    The batched serving layer (``repro.serve``) packs concurrent user
    requests that share a lowered transition program into a ``(R, W)`` seed
    matrix — one row per request, rows padded with ``-1`` to the cohort's
    walker width — and runs them all in a single fused launch.  Each row
    carries its own PRNG key (``keys``: R stacked keys), so row ``r`` of the
    result is bit-identical to the standalone call
    ``random_walk(graph, seeds[r], keys[r], ...)`` on either backend: the
    fused launch is a pure batching transform (``vmap`` over the request
    axis), never a semantic one.  Requests are isolated by construction —
    no RNG stream, carry state, or bias evaluation crosses rows.

    Returns a :class:`WalkResult` with a leading request axis: ``walks``
    ``(R, W, depth+1)``, ``lengths`` ``(R, W)``, ``sampled_edges`` ``(R,)``.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import algorithms as alg
    >>> from repro.core.engine import random_walk, random_walk_segments
    >>> from repro.graph import csr_from_edges
    >>> g = csr_from_edges(4, [0, 1, 2, 3], [1, 2, 3, 0], symmetrize=True)
    >>> seeds = jnp.array([[0, 1, -1, -1],   # request 0: 2 walkers (padded)
    ...                    [2, 3, 1, 0]])    # request 1: 4 walkers
    >>> keys = jax.vmap(jax.random.fold_in, (None, 0))(
    ...     jax.random.PRNGKey(7), jnp.arange(2))
    >>> fused = random_walk_segments(g, seeds, keys, depth=3,
    ...                              spec=alg.deepwalk(), max_degree=2)
    >>> solo = random_walk(g, seeds[1], keys[1], depth=3,
    ...                    spec=alg.deepwalk(), max_degree=2)
    >>> bool(jnp.array_equal(fused.walks[1], solo.walks))
    True
    """
    sel_methods, tables = flat_method_plan(graph, tp.lower(spec), max_degree)
    return _random_walk_segments(
        graph, seeds, keys, tables, depth=depth, spec=spec, max_degree=max_degree,
        method=method, backend=backend, sel_methods=sel_methods,
    )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "spec", "max_degree", "method", "backend", "sel_methods"),
)
def _random_walk_segments(graph, seeds, keys, tables, *, depth, spec, max_degree,
                          method, backend, sel_methods):
    # the OUTER jit is what makes fused serving cheap: a jitted callee
    # invoked under vmap is traced inline (no cache), so without this
    # wrapper every fused launch would re-trace the walk per call.  The
    # selection plan is computed ONCE by the public wrapper (vmapping the
    # public random_walk would hand its planner a traced graph); tables are
    # closed over, i.e. broadcast across the request axis.
    inner = functools.partial(
        _random_walk_impl, depth=depth, spec=spec, max_degree=max_degree,
        method=method, backend=backend, sel_methods=sel_methods,
    )
    return jax.vmap(lambda s, k: inner(graph, s, k, tables))(seeds, keys)


class SampleResult(NamedTuple):
    edges_src: jax.Array  # (I, cap) int32 sampled edge sources (-1 pad)
    edges_dst: jax.Array  # (I, cap) int32 sampled edge dests
    num_edges: jax.Array  # (I,) per-instance sampled edge count
    frontier_pool: jax.Array  # (I, C) final pool
    iters: jax.Array  # () total selection retry iterations (Fig. 11)
    searches: jax.Array  # () total CTPS searches (Fig. 12)


@functools.partial(
    jax.jit,
    static_argnames=("depth", "spec", "max_degree", "pool_capacity", "method", "max_vertices", "backend"),
)
def traversal_sample(
    graph: CSRGraph,
    seed_pools: jax.Array,  # (I, S) initial pools, -1 padded
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    pool_capacity: int,
    method: str = "its_brs",
    max_vertices: int = 0,  # >0 enables visited bitmap of that many vertices
    backend: bk.Backend = "auto",
) -> SampleResult:
    """Paper Fig. 2(b) MAIN: iterate SELECT-frontier / GATHER / SELECT-neighbors / UPDATE.

    The depth loop is a single ``jax.lax.scan`` over preallocated edge
    buffers, so trace/compile size is independent of ``depth``.

    Example — 2-hop neighbor sampling from two 1-seed instances on a toy
    4-cycle (every sampled edge is a real graph edge):

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import algorithms as alg
    >>> from repro.core.engine import traversal_sample
    >>> from repro.graph import csr_from_edges
    >>> g = csr_from_edges(4, [0, 1, 2, 3], [1, 2, 3, 0], symmetrize=True)
    >>> res = traversal_sample(g, jnp.array([[0], [2]]), jax.random.PRNGKey(0),
    ...                        depth=2, spec=alg.unbiased_neighbor_sampling(),
    ...                        max_degree=2, pool_capacity=8,
    ...                        max_vertices=g.num_vertices)
    >>> res.edges_src.shape  # (instances, depth * frontier * neighbor)
    (2, 32)
    >>> bool(jnp.all(res.num_edges >= 1))
    True
    """
    num_inst, _ = seed_pools.shape
    be = bk.resolve_backend(backend)
    program = tp.lower(spec)
    fs, ns = spec.frontier_size, spec.neighbor_size
    edges_per_iter = fs * ns if spec.per_vertex else ns
    cap = depth * edges_per_iter
    track = spec.track_visited and max_vertices > 0

    pool0 = jnp.full((num_inst, pool_capacity), -1, jnp.int32)
    pool0 = pool0.at[:, : seed_pools.shape[1]].set(seed_pools.astype(jnp.int32))
    if track:
        visited0 = jnp.zeros((num_inst, max_vertices), bool)
        seed_oh = jax.nn.one_hot(jnp.maximum(seed_pools, 0), max_vertices, dtype=bool)
        visited0 = visited0 | jnp.any(seed_oh & (seed_pools >= 0)[..., None], axis=1)
    else:
        visited0 = jnp.zeros((num_inst, 1), bool)  # inert carry placeholder

    def step(carry, it):
        pool, visited, esrc, edst, ecnt, tot_iters, tot_searches = carry
        kit = jax.random.fold_in(key, it)
        # ---- SELECT frontier from pool (line 4) --------------------------
        pmask = pool >= 0
        vctx = VertexCtx(v=pool, deg=jnp.where(pmask, _degree(graph, pool), 0), depth=it)
        vbias = jnp.where(pmask, spec.vertex_bias(vctx), 0.0)
        fres = bk.select_without_replacement(
            jax.random.fold_in(kit, 0), vbias, pmask, fs, method=method, backend=be
        )
        frontier = jnp.where(
            fres.valid, jnp.take_along_axis(pool, jnp.maximum(fres.indices, 0), axis=-1), -1
        )  # (I, fs)
        tot_iters = tot_iters + jnp.sum(fres.iters)
        tot_searches = tot_searches + jnp.sum(fres.searches)

        # ---- GATHER + EDGEBIAS (lines 5-6) ------------------------------
        ctx, emask = _edge_ctx(graph, frontier, jnp.full_like(frontier, -1), it, max_degree, spec.needs_prev_neighbors)
        ebias = jnp.where(emask, spec.edge_bias(ctx), 0.0)
        if track:
            seen = jnp.take_along_axis(
                visited[:, None, :], jnp.maximum(ctx.u, 0), axis=-1
            ) & (ctx.u >= 0)
            ebias = jnp.where(seen, 0.0, ebias)
            emask = emask & ~seen

        if spec.per_vertex:
            # independent NeighborPool per frontier vertex (neighbor sampling)
            nres = bk.select_without_replacement(
                jax.random.fold_in(kit, 1), ebias, emask, ns, method=method, backend=be
            )
            src = jnp.broadcast_to(frontier[..., None], frontier.shape + (ns,))
            dst = jnp.where(
                nres.valid, jnp.take_along_axis(ctx.u, jnp.maximum(nres.indices, 0), axis=-1), -1
            )
            if spec.burn_prob is not None:
                # forest fire: keep a geometric(p_f) prefix of the ns draws
                g = jax.random.uniform(jax.random.fold_in(kit, 7), dst.shape)
                keep = jnp.cumprod((g < spec.burn_prob).astype(jnp.int32), axis=-1) > 0
                keep = keep | (jnp.arange(ns) == 0)  # burn at least one
                dst = jnp.where(keep, dst, -1)
            src, dst = src.reshape(num_inst, -1), dst.reshape(num_inst, -1)
            if spec.track_visited:
                # sampling-without-replacement across the whole instance:
                # two frontier vertices may draw the same neighbor in the
                # same round (separate NeighborPools) — keep the first.
                eq = dst[..., :, None] == dst[..., None, :]
                both = (dst >= 0)[..., :, None] & (dst >= 0)[..., None, :]
                k_flat = dst.shape[-1]
                tri = jnp.tril(jnp.ones((k_flat, k_flat), bool), -1)
                dup = jnp.any(eq & both & tri, axis=-1)
                dst = jnp.where(dup, -1, dst)
            valid = dst >= 0
            tot_iters = tot_iters + jnp.sum(nres.iters)
            tot_searches = tot_searches + jnp.sum(nres.searches)
        else:
            # pooled NeighborPool over all frontier vertices (layer / MDRW)
            flat_bias = ebias.reshape(num_inst, -1)
            flat_mask = emask.reshape(num_inst, -1)
            flat_u = ctx.u.reshape(num_inst, -1)
            flat_v = jnp.broadcast_to(frontier[..., None], ctx.u.shape).reshape(num_inst, -1)
            nres = bk.select_without_replacement(
                jax.random.fold_in(kit, 1), flat_bias, flat_mask, ns, method=method, backend=be
            )
            gi = jnp.maximum(nres.indices, 0)
            src = jnp.where(nres.valid, jnp.take_along_axis(flat_v, gi, axis=-1), -1)
            dst = jnp.where(nres.valid, jnp.take_along_axis(flat_u, gi, axis=-1), -1)
            valid = dst >= 0
            tot_iters = tot_iters + jnp.sum(nres.iters)
            tot_searches = tot_searches + jnp.sum(nres.searches)

        # ---- record sampled edges (line 8) -------------------------------
        esrc = jax.lax.dynamic_update_slice(esrc, src, (0, it * edges_per_iter))
        edst = jax.lax.dynamic_update_slice(edst, dst, (0, it * edges_per_iter))
        ecnt = ecnt + jnp.sum(valid, axis=-1, dtype=jnp.int32)

        # ---- UPDATE pool (line 7) ----------------------------------------
        ectx_flat = EdgeCtx(
            v=src, u=dst, weight=jnp.ones_like(dst, jnp.float32),
            deg_v=jnp.where(src >= 0, _degree(graph, src), 0),
            deg_u=jnp.where(dst >= 0, _degree(graph, dst), 0),
            prev=jnp.full((num_inst,), -1, jnp.int32), is_prev_neighbor=None, depth=it,
        )
        # UPDATE lowers to the same fused epilogue the walk engines run
        new_v = tp.apply_epilogue(jax.random.fold_in(kit, 2), program, spec, ectx_flat, dst)
        new_v = jnp.where(valid, new_v, -1)
        if track:
            oh = jax.nn.one_hot(jnp.maximum(new_v, 0), max_vertices, dtype=bool)
            visited = visited | jnp.any(oh & (new_v >= 0)[..., None], axis=1)
        if spec.replace_selected:
            # MDRW: drop selected frontier vertices from the pool, insert new.
            drop = jnp.any(pool[..., :, None] == jnp.where(frontier >= 0, frontier, -2)[..., None, :], axis=-1)
            pool = jnp.where(drop, -1, pool)
            pool = _insert_into_pool(pool, new_v)
        elif spec.per_vertex:
            # BFS-style: next pool is exactly the newly sampled layer.
            pool = jnp.full_like(pool, -1)
            pool = _insert_into_pool(pool, new_v)
        else:
            pool = _insert_into_pool(pool, new_v)
        return (pool, visited, esrc, edst, ecnt, tot_iters, tot_searches), None

    init = (
        pool0,
        visited0,
        jnp.full((num_inst, cap), -1, jnp.int32),
        jnp.full((num_inst, cap), -1, jnp.int32),
        jnp.zeros((num_inst,), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
    )
    (pool, _, esrc, edst, ecnt, tot_iters, tot_searches), _ = jax.lax.scan(
        step, init, jnp.arange(depth)
    )
    return SampleResult(esrc, edst, ecnt, pool, tot_iters, tot_searches)


def _insert_into_pool(pool: jax.Array, new_v: jax.Array) -> jax.Array:
    """Insert new vertices into -1 slots (left-compacting both sides).

    Single cumsum-based compaction over the concatenated (pool, new) row:
    surviving pool entries keep their relative order in slots 0..n-1, new
    entries append after them, overflow past capacity is dropped (DESIGN.md
    §7 — replaces the earlier double argsort).
    """
    cap = pool.shape[-1]
    merged = jnp.concatenate([pool, new_v], axis=-1)
    valid = merged >= 0
    pos = jnp.cumsum(valid, axis=-1) - 1  # target slot of each valid entry
    ok = valid & (pos < cap)
    onehot = (pos[..., None] == jnp.arange(cap)) & ok[..., None]
    return jnp.max(jnp.where(onehot, merged[..., None], -1), axis=-2)
