"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295].

Assigned: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=("global",),
    activation="geglu",
    glu=True,
    emb_scale=True,
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
    reduce_dtype="bf16",  # §Perf gemma-7b it.1: 2x TP wire on TPU target
)

SMOKE = ModelConfig(
    name="gemma-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    pattern=("global",),
    activation="geglu",
    glu=True,
    emb_scale=True,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
