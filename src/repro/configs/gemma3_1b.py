"""gemma3-1b [dense] — 5:1 local:global, 128k ctx [hf:google/gemma-3-1b-pt].

Assigned: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
Pattern (local×5, global) ×4 + 2 tail local layers; window 512; GeGLU;
RoPE theta 1M on globals (single theta used here); qk-norm; emb scaling.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    pattern=("local",) * 5 + ("global",),
    window_size=512,
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    activation="geglu",
    glu=True,
    emb_scale=True,
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("local", "local", "global"),
    window_size=16,
    use_qk_norm=True,
    activation="geglu",
    glu=True,
    emb_scale=True,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
