"""Pallas TPU kernel: fused ITS selection with bipartite region search.

TPU mapping of the paper's warp-centric SELECT (DESIGN.md §2, §6):

- grid over *instance blocks* — each grid step owns ``(BLK_I, P)`` bias rows
  resident in VMEM (the paper's "one warp per instance" becomes "one tile of
  instances per grid step"; the K draws of an instance occupy vector lanes).
- prefix-sum + normalize + search + BRS retry are fused in one kernel: the
  CTPS never round-trips to HBM (the paper's key win over updated sampling).
- all gathers are one-hot contractions (MXU) — no atomics, no irregular
  addressing; within-round collisions resolve by lane priority (K×K conflict
  matrix), replacing the strided atomic bitmap.
- the retry budget is a static ``ITERS`` unroll of pre-generated randoms
  (counted RNG outside the kernel keeps it deterministic and testable).

VMEM budget: biases+CTPS+mask ≈ 3·BLK_I·P·4B; with BLK_I=8, P=2048 ≈ 200 KiB,
comfortably inside ~16 MiB VMEM with room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


def _its_select_kernel(biases_ref, rands_ref, out_ref, stats_ref, *, iters: int, k: int):
    b = jnp.maximum(biases_ref[...].astype(jnp.float32), 0.0)  # (BLK_I, P)
    blk_i, p = b.shape
    sums = jnp.cumsum(b, axis=-1)
    total = jnp.maximum(sums[:, -1:], _EPS)
    ctps = sums / total
    lower = jnp.concatenate([jnp.zeros_like(ctps[:, :1]), ctps[:, :-1]], axis=-1)
    navail = jnp.sum((b > 0).astype(jnp.int32), axis=-1)
    want = jnp.minimum(navail, k)
    lane = jax.lax.broadcasted_iota(jnp.int32, (blk_i, k), 1)

    done = lane >= want[:, None]
    out = jnp.full((blk_i, k), -1, jnp.int32)
    selmask = jnp.zeros((blk_i, p), jnp.float32)
    it_acc = jnp.zeros((blk_i,), jnp.int32)
    se_acc = jnp.zeros((blk_i,), jnp.int32)

    def gather(table, idx):
        oh = (idx[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (blk_i, k, p), 2)).astype(
            table.dtype
        )
        return jnp.einsum("ikp,ip->ik", oh, table, preferred_element_type=jnp.float32)

    def search(r):
        idx = jnp.sum((ctps[:, None, :] <= r[:, :, None]).astype(jnp.int32), axis=-1)
        return jnp.clip(idx, 0, p - 1)

    def body(it, carry):
        done, out, selmask, it_acc, se_acc = carry
        pending = ~done
        r1 = jax.lax.dynamic_slice_in_dim(rands_ref[...], it, 1, axis=1)[:, 0, :]
        idx1 = search(r1)
        hit1 = gather(selmask, idx1) > 0.5
        # retry-loop accounting (paper Figs. 11/12), bit-identical to the
        # reference loop in core.select._select_its_loop
        it_acc = it_acc + jnp.any(pending, axis=-1).astype(jnp.int32)
        se_acc = se_acc + jnp.sum(pending.astype(jnp.int32), axis=-1)
        se_acc = se_acc + jnp.sum((pending & hit1).astype(jnp.int32), axis=-1)
        l = gather(lower, idx1)
        h = gather(ctps, idx1)
        delta = h - l
        r2 = r1 * (1.0 - delta)
        r2 = jnp.where(r2 < l, r2, r2 + delta)
        r2 = jnp.clip(r2, 0.0, 1.0 - _EPS)
        idx2 = search(r2)
        hit2 = gather(selmask, idx2) > 0.5
        cand = jnp.where(hit1, idx2, idx1)
        ok = jnp.logical_and(~done, ~jnp.where(hit1, hit2, hit1))
        ok = jnp.logical_and(ok, gather(b, cand) > 0)
        # K×K conflict matrix: lowest lane wins (replaces atomic bitmap)
        eq = cand[:, :, None] == cand[:, None, :]
        both = ok[:, :, None] & ok[:, None, :]
        tri = (
            jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
            < jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
        )
        beaten = jnp.any(eq & both & tri[None], axis=-1)
        win = ok & ~beaten
        out = jnp.where(win, cand, out)
        oh = (
            cand[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (blk_i, k, p), 2)
        ) & win[:, :, None]
        selmask = jnp.maximum(selmask, jnp.max(oh.astype(jnp.float32), axis=1))
        done = done | win
        got = jnp.sum(done.astype(jnp.int32), axis=-1)
        done = done | ((got >= want)[:, None] & (lane >= want[:, None]))
        return done, out, selmask, it_acc, se_acc

    done, out, selmask, it_acc, se_acc = jax.lax.fori_loop(
        0, iters, body, (done, out, selmask, it_acc, se_acc)
    )
    out_ref[...] = out
    stats_ref[...] = jnp.stack([it_acc, se_acc], axis=-1)


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → interpret off-TPU, compile through Mosaic on TPU."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("blk_i", "interpret", "with_stats"))
def its_select_pallas(
    biases: jax.Array,
    rands: jax.Array,
    *,
    blk_i: int = 8,
    interpret: bool | None = None,
    with_stats: bool = False,
):
    """Fused without-replacement ITS+BRS selection.

    biases: (I, P) float — per-instance candidate biases (<=0 → unselectable).
    rands:  (I, ITERS, K) float — pre-generated retry budget.
    Returns indices (I, K) int32 (-1 = unfilled); with ``with_stats=True``
    also an (I, 2) int32 array of (retry iterations, CTPS searches) per
    instance (paper Figs. 11/12 accounting).

    Any I works — instances are padded internally to a multiple of ``blk_i``
    and the pad rows sliced off.  P should be lane-aligned (multiple of 128)
    for best TPU layout (any P works functionally; the dispatcher in
    ``core.backend`` pads pools to lane multiples, DESIGN.md §6).
    """
    i_dim, p = biases.shape
    iters, k = rands.shape[1], rands.shape[2]
    pad_i = (-i_dim) % blk_i
    if pad_i:
        # zero-bias pad rows select nothing; sliced off below
        biases = jnp.pad(biases, ((0, pad_i), (0, 0)))
        rands = jnp.pad(rands, ((0, pad_i), (0, 0), (0, 0)))
    i_pad = i_dim + pad_i
    kernel = functools.partial(_its_select_kernel, iters=iters, k=k)
    out, stats = pl.pallas_call(
        kernel,
        grid=(i_pad // blk_i,),
        in_specs=[
            pl.BlockSpec((blk_i, p), lambda i: (i, 0)),
            pl.BlockSpec((blk_i, iters, k), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_i, k), lambda i: (i, 0)),
            pl.BlockSpec((blk_i, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((i_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((i_pad, 2), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(biases, rands)
    if with_stats:
        return out[:i_dim], stats[:i_dim]
    return out[:i_dim]
