"""Algorithm zoo: paper Table I expressed through the C-SAW bias API.

Each constructor returns a :class:`SamplingSpec`.  The point of the paper's
API is that *all* of these fit the same three hooks; this module is the
living proof (and the test surface for expressiveness).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import (
    EdgeCtx,
    SamplingSpec,
    degree_edge_bias,
    degree_vertex_bias,
    identity_update,
    uniform_edge_bias,
    uniform_vertex_bias,
    weight_edge_bias,
)
from repro.core.transition import (
    FlatBias,
    MHAcceptEpilogue,
    TeleportEpilogue,
    TransitionProgram,
    WindowBias,
)

# ---------------------------------------------------------------------------
# Random walks (NeighborSize = 1 per step)
# ---------------------------------------------------------------------------


# flat per-edge biases (CSR order) unlocking the compiled walk fast path;
# each must agree with its EdgeCtx counterpart on every real edge
def _flat_uniform(g) -> jax.Array:
    return jnp.ones_like(g.weights)


def _flat_weight(g) -> jax.Array:
    return g.weights


def _flat_degree(g) -> jax.Array:
    deg = g.indptr[1:] - g.indptr[:-1]
    return deg[g.indices].astype(jnp.float32)


def deepwalk() -> SamplingSpec:
    """Unbiased simple random walk (DeepWalk)."""
    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        flat_edge_bias=_flat_uniform,
        transition=TransitionProgram(bias=FlatBias(_flat_uniform)),
        name="deepwalk",
        track_visited=False,
    )


def biased_random_walk() -> SamplingSpec:
    """Static biased walk: neighbor degree as bias (Biased DeepWalk)."""
    return SamplingSpec(
        edge_bias=degree_edge_bias,
        flat_edge_bias=_flat_degree,
        transition=TransitionProgram(bias=FlatBias(_flat_degree)),
        name="biased_rw",
        track_visited=False,
    )


def weighted_random_walk() -> SamplingSpec:
    """Static biased walk on edge weights."""
    return SamplingSpec(
        edge_bias=weight_edge_bias,
        flat_edge_bias=_flat_weight,
        transition=TransitionProgram(bias=FlatBias(_flat_weight)),
        name="weighted_rw",
        track_visited=False,
    )


def node2vec(p: float = 2.0, q: float = 0.5) -> SamplingSpec:
    """Dynamic bias from the previous step (paper Fig. 3(a)).

    The bias reads only per-edge context (u, weight, is-prev-neighbor) and
    the carried prev vertex, so it declares a :class:`WindowBias` and runs
    degree-bucketed on the kernel's gathered edge windows — never on the
    dense ``(W, max_degree)`` gather.
    """

    def edge_bias(ctx: EdgeCtx) -> jax.Array:
        w = ctx.weight
        back = ctx.u == ctx.prev[..., None]
        near = ctx.is_prev_neighbor
        first_step = (ctx.prev < 0)[..., None]
        bias = jnp.where(near, w, w * (1.0 / q))
        bias = jnp.where(back, w * (1.0 / p), bias)
        return jnp.where(first_step, w, bias)

    return SamplingSpec(
        edge_bias=edge_bias,
        needs_prev_neighbors=True,
        transition=TransitionProgram(
            bias=WindowBias(
                edge_bias, needs_prev_neighbors=True,
                needs_deg_u=False,  # bias reads weights/membership only
            )
        ),
        name="node2vec",
        track_visited=False,
    )


def metropolis_hastings_walk() -> SamplingSpec:
    """MHRW: propose uniform neighbor u, accept w.p. min(1, deg(v)/deg(u))."""

    def update(key: jax.Array, ctx: EdgeCtx, u: jax.Array) -> jax.Array:
        deg_u = jnp.where(u >= 0, jnp.take_along_axis(ctx.deg_u, jnp.argmax(ctx.u == u[..., None], -1)[..., None], -1)[..., 0], 1)
        accept_p = jnp.minimum(1.0, ctx.deg_v / jnp.maximum(deg_u, 1))
        stay = jax.random.uniform(key, u.shape) >= accept_p
        return jnp.where(stay & (ctx.v >= 0), ctx.v, u)

    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        flat_edge_bias=_flat_uniform,
        update=update,
        transition=TransitionProgram(
            bias=FlatBias(_flat_uniform), epilogue=MHAcceptEpilogue()
        ),
        name="mhrw",
        track_visited=False,
    )


def random_walk_with_jump(jump_prob: float, num_vertices: int) -> SamplingSpec:
    """Jump to a uniformly random vertex with probability ``jump_prob``."""

    def update(key: jax.Array, ctx: EdgeCtx, u: jax.Array) -> jax.Array:
        kj, kv = jax.random.split(key)
        jump = jax.random.uniform(kj, u.shape) < jump_prob
        tgt = jax.random.randint(kv, u.shape, 0, num_vertices)
        return jnp.where(jump, tgt, u)

    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        flat_edge_bias=_flat_uniform,
        update=update,
        transition=TransitionProgram(
            bias=FlatBias(_flat_uniform),
            epilogue=TeleportEpilogue(jump_prob, "uniform", num_vertices=num_vertices),
        ),
        name="rw_jump",
        track_visited=False,
    )


def random_walk_with_restart(restart_prob: float, home: int | None = None) -> SamplingSpec:
    """Restart with probability ``restart_prob``: to the predetermined vertex
    ``home``, or (``home=None``) to the walk's own seed — the engines carry
    the per-instance home vertex as transition-program state."""

    def update(key: jax.Array, ctx: EdgeCtx, u: jax.Array) -> jax.Array:
        if home is None:
            raise NotImplementedError(
                "restart-to-seed needs the engines' home carry; use the "
                "transition-program path (spec.transition), not the raw hook"
            )
        restart = jax.random.uniform(key, u.shape) < restart_prob
        return jnp.where(restart, jnp.full_like(u, home), u)

    epilogue = (
        TeleportEpilogue(restart_prob, "home")
        if home is None
        else TeleportEpilogue(restart_prob, "fixed", vertex=home)
    )
    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        flat_edge_bias=_flat_uniform,
        update=update,
        transition=TransitionProgram(bias=FlatBias(_flat_uniform), epilogue=epilogue),
        name="rw_restart",
        track_visited=False,
    )


# ---------------------------------------------------------------------------
# Traversal-based sampling (frontier pools)
# ---------------------------------------------------------------------------


def unbiased_neighbor_sampling(neighbor_size: int = 2, frontier_size: int = 8) -> SamplingSpec:
    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        frontier_size=frontier_size,
        neighbor_size=neighbor_size,
        per_vertex=True,
        name="neighbor_unbiased",
    )


def biased_neighbor_sampling(neighbor_size: int = 2, frontier_size: int = 8) -> SamplingSpec:
    """Constant NeighborSize per vertex, edge-weight bias."""
    return SamplingSpec(
        edge_bias=weight_edge_bias,
        frontier_size=frontier_size,
        neighbor_size=neighbor_size,
        per_vertex=True,
        name="neighbor_biased",
    )


def forest_fire_sampling(p_f: float = 0.7, max_burn: int = 8, frontier_size: int = 8) -> SamplingSpec:
    """Probabilistic neighbor sampling: geometric(p_f) burn count per vertex."""
    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        frontier_size=frontier_size,
        neighbor_size=max_burn,
        per_vertex=True,
        burn_prob=p_f,
        name="forest_fire",
    )


def layer_sampling(neighbor_size: int = 8, frontier_size: int = 8) -> SamplingSpec:
    """Constant NeighborSize per *layer* over the pooled frontier neighbors."""
    return SamplingSpec(
        edge_bias=weight_edge_bias,
        frontier_size=frontier_size,
        neighbor_size=neighbor_size,
        per_vertex=False,
        name="layer",
    )


def snowball_sampling(max_degree_keep: int = 16, frontier_size: int = 8) -> SamplingSpec:
    """Add (up to a cap of) all neighbors of every sampled vertex."""
    return SamplingSpec(
        edge_bias=uniform_edge_bias,
        frontier_size=frontier_size,
        neighbor_size=max_degree_keep,
        per_vertex=True,
        name="snowball",
    )


def multi_dimensional_random_walk(frontier_size: int = 1) -> SamplingSpec:
    """MDRW / frontier sampling (paper Figs. 3(b), 4): degree-biased frontier
    selection, uniform neighbor choice, selected vertex replaced in the pool."""
    return SamplingSpec(
        vertex_bias=degree_vertex_bias,
        edge_bias=uniform_edge_bias,
        update=identity_update,
        frontier_size=frontier_size,
        neighbor_size=1,
        per_vertex=False,
        replace_selected=True,
        track_visited=False,
        name="mdrw",
    )


ALGORITHMS = {
    "deepwalk": deepwalk,
    "biased_rw": biased_random_walk,
    "weighted_rw": weighted_random_walk,
    "node2vec": node2vec,
    "mhrw": metropolis_hastings_walk,
    "neighbor_unbiased": unbiased_neighbor_sampling,
    "neighbor_biased": biased_neighbor_sampling,
    "forest_fire": forest_fire_sampling,
    "layer": layer_sampling,
    "snowball": snowball_sampling,
    "mdrw": multi_dimensional_random_walk,
}
