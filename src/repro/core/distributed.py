"""Multi-device sampling (paper §V-D + beyond-paper graph sharding).

Paper-faithful mode — ``instance_parallel_walk``: sampling instances are
split into equal disjoint groups across devices, the graph is replicated,
and *no* inter-device communication happens (the paper's multi-GPU design).

Beyond-paper mode — graph sharding: the CSR is range-partitioned across
devices (each device owns a contiguous vertex range, HBM use scales 1/D)
and walkers are ROUTED to the shard owning their frontier vertex each step.
That owner-routed frontier-exchange engine lives in ``repro.shard``
(DESIGN.md §12); :func:`graph_sharded_walk` survives here as a thin
compatibility wrapper over it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.api import SamplingSpec
from repro.core.engine import WalkResult, random_walk
from repro.distributed.sharding import shard_map_compat
from repro.graph.csr import CSRGraph
from repro.shard.walk import (  # noqa: F401  (re-exported for compatibility)
    replicated_psum_walk,
    shard_graph_for_mesh,
    sharded_random_walk,
)


def instance_parallel_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> WalkResult:
    """Shard instances over ``axis``; replicate the graph; zero collectives."""

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(), P(axis), P()),
        out_specs=WalkResult(P(axis), P(axis), P()),
    )
    def _run(graph, seeds, key):
        # fold the axis SIZE, then the device index, into the key: device d
        # of a D-way mesh draws from stream (D, d), so the same seeds on 4-
        # and 8-device meshes use provably disjoint streams (distinct (D, d)
        # pairs), instead of device d colliding across mesh widths
        didx = jax.lax.axis_index(axis)
        ndev = jnp.int32(mesh.shape[axis])  # static: mesh is closed over
        kdev = jax.random.fold_in(jax.random.fold_in(key, ndev), didx)
        res = random_walk(graph, seeds, kdev,
                          depth=depth, spec=spec, max_degree=max_degree)
        return WalkResult(res.walks, res.lengths,
                          jax.lax.psum(res.sampled_edges, axis))

    return _run(graph, seeds, key)


def graph_sharded_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> jax.Array:
    """Compatibility wrapper: walks over a device-sharded graph.

    Returns walks (I, depth+1).  Delegates to
    :func:`repro.shard.sharded_random_walk` — the owner-routed
    frontier-exchange engine (per-device HBM ∝ 1/D, one ``all_to_all`` per
    round, bit-identical to single-device ``random_walk`` for flat- and
    window-bias programs).  Specs outside that envelope take its
    replicated-``psum`` fallback, the design this wrapper used to implement
    inline.
    """
    res = sharded_random_walk(
        mesh, graph, seeds, key,
        depth=depth, spec=spec, max_degree=max_degree, axis=axis,
    )
    return res.walks
