"""Batched multi-instance sampling service (paper §V-C, lifted to requests).

Front door for serving many concurrent, heterogeneous sampling requests:
admission-controlled queueing, padding-bucket batching keyed on lowered
transition programs, fused device launches, per-request results.  Two
serving modes share the cohort machinery: the batch
:class:`SamplingService` (submit-then-drain) and the always-on
:class:`StreamingSamplingService` (continuous batching under latency
SLOs, priority tiers, per-tenant quotas — DESIGN.md §15).  See
``docs/api.md`` for the public surface and ``benchmarks/bench_serve.py``
for the fused-vs-sequential and open-loop latency numbers this layer
buys.
"""
from repro.serve.queue import (
    AdmissionError,
    Cohort,
    RequestQueue,
    SamplingRequest,
    ServiceConfig,
    cohort_key,
)
from repro.serve.service import (
    DrainError,
    RequestLatency,
    RequestResult,
    SamplingService,
    ServiceStats,
)
from repro.serve.stream import (
    Priority,
    StreamConfig,
    StreamFuture,
    StreamingSamplingService,
    TenantQuota,
)

__all__ = [
    "AdmissionError",
    "DrainError",
    "Cohort",
    "Priority",
    "RequestLatency",
    "RequestQueue",
    "RequestResult",
    "SamplingRequest",
    "SamplingService",
    "ServiceConfig",
    "ServiceStats",
    "StreamConfig",
    "StreamFuture",
    "StreamingSamplingService",
    "TenantQuota",
    "cohort_key",
]
