"""Quickstart: express and run sampling algorithms with the C-SAW API.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core.api import EdgeCtx, SamplingSpec
from repro.core.engine import random_walk, traversal_sample
from repro.graph import powerlaw_graph


def main() -> None:
    g = powerlaw_graph(20_000, exponent=2.1, seed=0, weighted=True)
    print(f"graph: V={g.num_vertices} E={g.num_edges} maxdeg={g.max_degree()}")
    key = jax.random.PRNGKey(0)
    md = min(g.max_degree(), 512)

    # 1) built-in algorithms ---------------------------------------------------
    seeds = jax.random.randint(key, (2048,), 0, g.num_vertices)
    for name in ("deepwalk", "biased_rw", "node2vec"):
        spec = alg.ALGORITHMS[name]()
        t0 = time.perf_counter()
        res = random_walk(g, seeds, key, depth=32, spec=spec, max_degree=md)
        jax.block_until_ready(res.walks)
        secs = time.perf_counter() - t0
        print(f"{name:12s} SEPS={int(res.sampled_edges)/secs:.3e}")

    # 2) traversal sampling ----------------------------------------------------
    pools = jax.random.randint(key, (512, 1), 0, g.num_vertices)
    res = traversal_sample(
        g, pools, key, depth=3, spec=alg.biased_neighbor_sampling(),
        max_degree=md, pool_capacity=256, max_vertices=g.num_vertices,
    )
    print(f"neighbor sampling: {float(res.num_edges.mean()):.1f} edges/instance, "
          f"{int(res.iters)} retry iters (BRS)")

    # 3) a CUSTOM algorithm via the three-hook API (paper Fig. 2a) -------------
    #    "temperature walk": bias ∝ weight^2, restart at dead ends
    def hot_edges(ctx: EdgeCtx) -> jax.Array:
        return jnp.square(ctx.weight)

    spec = SamplingSpec(edge_bias=hot_edges, name="custom_hot", track_visited=False)
    res = random_walk(g, seeds[:256], key, depth=16, spec=spec, max_degree=md)
    print(f"custom algorithm: {int(res.sampled_edges)} edges sampled "
          f"(mean len {float(res.lengths.mean()):.1f})")


if __name__ == "__main__":
    main()
