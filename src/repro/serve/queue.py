"""Request queue: admission control and padding-bucket batching.

The service's front door.  Independent user requests — each its own seed
set, walk length, and :class:`~repro.core.api.SamplingSpec` — are admitted
against capacity limits and grouped into **cohorts**: sets of requests that
one fused device launch can serve.  Two requests share a cohort iff

1. their specs lower to the same transition program
   (:func:`cohort_key` — one compiled trace then serves every request),
2. their walk lengths round up to the same depth bucket, and
3. their walker counts round up to the same width bucket,

so the packed seed matrix has one static shape per (program, depth-bucket,
width-bucket) triple and XLA's jit cache turns every recurring request mix
into a cache hit.  Padding buckets are powers of two: a request is never
padded past 2x its true size in either axis, and the number of distinct
traces stays logarithmic in the request-size range (ThunderRW's fused-step
insight applied to *inter-request* batching; FlexiWalker's per-query
heterogeneity handled by bucketing instead of recompilation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core.api import SamplingSpec
from repro.core import transition as tp


class AdmissionError(RuntimeError):
    """A request the queue refuses: malformed, oversized, or over capacity.

    Every limit-violation message names the violated limit and its
    configured value (``max_depth=512``, ``tenant_quota[t].walkers_per_s=...``)
    so callers — and the operators reading service logs — can tell back-
    pressure (drain/retry) from misconfiguration (resize the limit) without
    string-guessing.
    """


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Capacity limits and batching knobs of a :class:`~repro.serve.SamplingService`.

    max_pending_requests / max_pending_walkers: admission-control ceilings —
    ``submit`` raises :class:`AdmissionError` past either.
    max_walkers_per_request / max_depth: per-request size ceilings.
    min_walker_bucket / min_depth_bucket: smallest padding buckets (below,
    tiny requests share one bucket instead of fragmenting the jit cache).
    max_requests_per_launch: cap on the fused request axis ``R`` — larger
    cohorts split into several launches.
    fuse: ``False`` serves each request in its own launch (the benchmark
    baseline).  Results are bit-identical either way — fusing is a pure
    batching transform (``engine.random_walk_segments``).
    """

    max_pending_requests: int = 256
    max_pending_walkers: int = 1 << 18
    max_walkers_per_request: int = 1 << 14
    max_depth: int = 512
    min_walker_bucket: int = 16
    min_depth_bucket: int = 4
    max_requests_per_launch: int = 64
    fuse: bool = True


def _pow2_bucket(n: int, lo: int) -> int:
    """Smallest power of two >= max(n, lo)."""
    return max(lo, 1 << max(n - 1, 0).bit_length())


def cohort_key(spec: SamplingSpec) -> tuple:
    """The fusion key: requests with equal keys may share one device launch.

    The lowered transition program (``core.transition.lower``) captures the
    full step semantics of flat- and window-bias specs with declarative
    epilogues, so program equality alone suffices there.  Opaque parts are
    bottomless (``OpaqueBias() == OpaqueBias()`` says nothing about the
    hooks), so the raw callables join the key for them — two requests built
    from the *same* hook functions still fuse; distinct closures never do.
    """
    program = tp.lower(spec)
    extras: list = []
    if program.mode == "opaque":
        extras += [spec.edge_bias, spec.needs_prev_neighbors]
    if isinstance(program.epilogue, tp.OpaqueEpilogue):
        extras.append(spec.update)
    return (program, tuple(extras))


@dataclasses.dataclass(frozen=True)
class SamplingRequest:
    """One admitted user request, as the queue holds it."""

    request_id: int
    seeds: np.ndarray  # (n,) int32 host array, validated in [0, V)
    depth: int  # requested walk length (steps)
    spec: SamplingSpec
    key: jax.Array  # per-request PRNG key — isolates the request's stream

    @property
    def num_walkers(self) -> int:
        return int(self.seeds.shape[0])


@dataclasses.dataclass(frozen=True)
class Cohort:
    """Requests one fused launch serves, plus the launch's padded geometry."""

    key: tuple
    requests: Tuple[SamplingRequest, ...]
    depth: int  # depth bucket: max over members, rounded up to a power of 2
    width: int  # walker bucket: per-request padded row width

    @property
    def num_walkers(self) -> int:
        return sum(r.num_walkers for r in self.requests)


def validate_request(request: SamplingRequest, config: ServiceConfig) -> None:
    """Per-request admission checks (shape + size ceilings) or raise
    :class:`AdmissionError`.

    Shared by the batch queue and the streaming front door
    (``serve.stream``) so both admit exactly the same request population —
    a request the batch service would serve is never shed by the stream and
    vice versa.
    """
    n = request.num_walkers
    if request.seeds.ndim != 1 or n == 0:
        raise AdmissionError(
            f"request {request.request_id}: seeds must be a non-empty "
            f"1-D array, got shape {request.seeds.shape}"
        )
    if n > config.max_walkers_per_request:
        raise AdmissionError(
            f"request {request.request_id}: {n} walkers > "
            f"max_walkers_per_request={config.max_walkers_per_request}"
        )
    if not 1 <= request.depth <= config.max_depth:
        raise AdmissionError(
            f"request {request.request_id}: depth {request.depth} outside "
            f"[1, max_depth={config.max_depth}]"
        )


def check_capacity(
    pending_requests: int, pending_walkers: int, incoming_walkers: int,
    config: ServiceConfig,
) -> None:
    """Back-pressure ceilings over a pending population, or raise
    :class:`AdmissionError` (callers should drain/await and retry, or shed
    load).  Shared by the batch queue and the streaming backlog."""
    if pending_requests >= config.max_pending_requests:
        raise AdmissionError(
            f"queue full: {pending_requests} pending requests "
            f"(max_pending_requests={config.max_pending_requests}); drain first"
        )
    if pending_walkers + incoming_walkers > config.max_pending_walkers:
        raise AdmissionError(
            f"queue full: {pending_walkers}+{incoming_walkers} walkers > "
            f"max_pending_walkers={config.max_pending_walkers}; drain first"
        )


class RequestQueue:
    """Admission control + cohort formation over pending requests."""

    def __init__(self, config: ServiceConfig):
        self.config = config
        self._pending: List[SamplingRequest] = []
        self._pending_walkers = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending_walkers(self) -> int:
        return self._pending_walkers

    def submit(self, request: SamplingRequest) -> None:
        """Admit ``request`` or raise :class:`AdmissionError`.

        Shape/size checks guard the launch geometry; the two pending-total
        ceilings are the service's back-pressure signal (callers should
        ``drain()`` and retry, or shed load).
        """
        validate_request(request, self.config)
        check_capacity(
            len(self._pending), self._pending_walkers,
            request.num_walkers, self.config,
        )
        self._pending.append(request)
        self._pending_walkers += request.num_walkers

    def take_cohorts(self, bucket_by_shape: bool = True) -> List[Cohort]:
        """Group and remove all pending requests into padded cohorts.

        With ``bucket_by_shape`` (the in-memory fused path), requests are
        bucketed by ``(cohort_key(spec), depth bucket, width bucket)`` in
        arrival order — every member shares the launch's padded geometry.
        Without it (the out-of-memory path, where per-instance
        ``depth_limits`` absorb heterogeneous walk lengths and requests
        concatenate along one flat instance axis), only the transition
        program keys the grouping — the §V-C ideal of one merged queue pass
        per algorithm.  Each group splits into cohorts of at most
        ``max_requests_per_launch`` members.

        **Ordering contract** (deterministic, FIFO-fair — the streaming
        scheduler and the OOM/sharded launch-key discipline both depend on
        it): within a cohort key, members appear in submission order (the
        queue appends on ``submit`` and never reorders), so a request's row
        in the packed launch — and hence its flat instance index on the
        OOM/sharded paths — is fixed by the submission history alone.
        Across keys, cohorts are returned in order of each group's
        *earliest* member submission, and a group's cohorts (when it splits
        at ``max_requests_per_launch``) stay in member order.  Two queues
        fed the same submission sequence produce identical cohort lists.
        """
        cfg = self.config
        groups: Dict[tuple, List[SamplingRequest]] = {}
        for req in self._pending:
            ck = cohort_key(req.spec)
            gk: tuple = (ck,)
            if bucket_by_shape:
                gk = (
                    ck,
                    _pow2_bucket(req.depth, cfg.min_depth_bucket),
                    _pow2_bucket(req.num_walkers, cfg.min_walker_bucket),
                )
            groups.setdefault(gk, []).append(req)
        self._pending = []
        self._pending_walkers = 0

        cohorts = []
        for gk, reqs in groups.items():
            for at in range(0, len(reqs), cfg.max_requests_per_launch):
                members = tuple(reqs[at : at + cfg.max_requests_per_launch])
                if bucket_by_shape:
                    _, depth_b, width_b = gk
                else:
                    depth_b = _pow2_bucket(
                        max(r.depth for r in members), cfg.min_depth_bucket
                    )
                    width_b = max(r.num_walkers for r in members)
                cohorts.append(
                    Cohort(key=gk[0], requests=members, depth=depth_b, width=width_b)
                )
        return cohorts
