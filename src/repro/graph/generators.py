"""Synthetic graph generators (host-side numpy; deterministic by seed).

The paper evaluates on SNAP graphs (Table II).  Offline we reproduce the same
*structural regimes* with standard generators:

- ``rmat_graph``      — Graph500-style R-MAT (power-law, community structure),
                        matches the scale-free regime where BRS shines.
- ``powerlaw_graph``  — configuration-model power-law degree sequence.
- ``erdos_renyi_graph`` — uniform-degree control.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, csr_from_edges


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    weighted: bool = False,
) -> CSRGraph:
    """R-MAT generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    w = rng.random(m).astype(np.float32) + 0.1 if weighted else None
    return csr_from_edges(n, src, dst, weights=w, symmetrize=True)


def erdos_renyi_graph(
    num_vertices: int, avg_degree: float, seed: int = 0, weighted: bool = False
) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(num_vertices * avg_degree / 2)
    src = rng.integers(0, num_vertices, m)
    dst = rng.integers(0, num_vertices, m)
    w = rng.random(m).astype(np.float32) + 0.1 if weighted else None
    return csr_from_edges(num_vertices, src, dst, weights=w, symmetrize=True)


def powerlaw_graph(
    num_vertices: int,
    exponent: float = 2.1,
    min_degree: int = 2,
    max_degree: int | None = None,
    seed: int = 0,
    weighted: bool = False,
) -> CSRGraph:
    """Configuration-model graph with a power-law degree sequence."""
    rng = np.random.default_rng(seed)
    if max_degree is None:
        max_degree = max(min_degree + 1, int(np.sqrt(num_vertices)))
    # Inverse-CDF sampling of degrees ~ k^-exponent on [min_degree, max_degree]
    u = rng.random(num_vertices)
    a = 1.0 - exponent
    lo, hi = float(min_degree), float(max_degree)
    deg = ((lo**a + u * (hi**a - lo**a)) ** (1.0 / a)).astype(np.int64)
    stubs = np.repeat(np.arange(num_vertices, dtype=np.int64), deg)
    rng.shuffle(stubs)
    if stubs.size % 2:
        stubs = stubs[:-1]
    half = stubs.size // 2
    src, dst = stubs[:half], stubs[half:]
    w = rng.random(src.size).astype(np.float32) + 0.1 if weighted else None
    return csr_from_edges(num_vertices, src, dst, weights=w, symmetrize=True)
