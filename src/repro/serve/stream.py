"""Always-on streaming sampling service: continuous batching under SLOs.

The batch :class:`~repro.serve.SamplingService` is submit-then-drain: a
closed world where every request is present before the first launch.  A
front-end serving continuous traffic lives on the *temporal* axis instead —
requests arrive at all times, each carries a latency budget, and the
scheduler's job is deciding **when** to launch a cohort, trading batching
efficiency (wait, so more requests share the launch) against latency
(launch, so the oldest request makes its deadline).  This module adds that
axis and nothing else: cohort *formation* (grouping, padding buckets,
packing, placement routing) is exactly PR 4's machinery, reached through
``SamplingService._run_cohort``, so a streamed request's walks are
bit-identical to the same request batch-served or launched standalone at
the padded geometry — streaming changes launch *timing*, never packing
*semantics*.

The scheduling policy (DESIGN.md §15):

- **Forming cohorts**: submitted requests join the forming cohort of their
  group key — the same ``(cohort_key, depth bucket, width bucket)`` the
  batch queue uses on the in-memory placement, program-only on the
  OOM/sharded placements — in strict arrival order (the
  ``take_cohorts`` FIFO contract).
- **Launch triggers**, per forming cohort: *fill* (the cohort reaches
  ``max_requests_per_launch`` — waiting longer buys nothing, the next
  arrival starts a new cohort anyway); *slack* (the most urgent member's
  remaining deadline slack approaches ``slack_factor ×`` the cohort key's
  measured launch cost — an EMA over observed launch wall times, so the
  policy adapts to what this graph/placement/program actually costs);
  *window* (a deadline-less request has waited ``max_batch_window_ms`` —
  the implied SLO that bounds every request's worst-case queueing).
- **Launch order**: among due cohorts, earliest effective deadline first
  (EDF); priority tiers break ties, then arrival order.  One launch at a
  time — device launches serialize anyway, and re-evaluating between
  launches lets late arrivals join still-forming cohorts.
- **Admission**: the batch service's per-request and back-pressure checks
  (``serve.queue``) apply verbatim to the streaming backlog, extended with
  per-tenant token buckets (``TenantQuota``: walkers/s refill, burst cap)
  — every rejection is an :class:`~repro.serve.queue.AdmissionError`
  naming the violated limit and its value.
- **Delivery**: per-request :class:`StreamFuture`\\ s (blocking ``result()``
  or ``add_done_callback``), never a global drain.  A failed cohort launch
  fails exactly its members' futures (with a
  :class:`~repro.serve.service.DrainError` carrying how much of the cohort
  completed); every other request is untouched.

Two execution modes share the scheduler: a background thread
(``start=True``, production / the open-loop benchmark) and synchronous
polling (``start=False`` + ``poll()``/``flush()`` with an injectable
``clock``), which makes every policy decision deterministically testable —
and is why arrival timing can be replayed bit-exactly in the parity
harness.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Callable, Dict, List, Mapping, Optional

import jax
import numpy as np

from repro.core.api import SamplingSpec
from repro.serve.queue import (
    AdmissionError,
    Cohort,
    SamplingRequest,
    _pow2_bucket,
    check_capacity,
    cohort_key,
    validate_request,
)
from repro.serve.service import (
    DrainError,
    RequestLatency,
    RequestResult,
    SamplingService,
)


class Priority(enum.IntEnum):
    """Request priority tiers — lower value preempts higher on deadline ties.

    Tiers order launches; they never change results (per-request RNG keys
    make a request's walks independent of when and with whom it launches).
    """

    INTERACTIVE = 0  # user-facing: short deadlines, launches first on ties
    STANDARD = 1  # the default tier
    BULK = 2  # corpus generation / backfill: yields ties to everyone


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant token bucket: sustained walkers/s with a burst allowance.

    A submit costs ``num_walkers`` tokens; the bucket refills continuously
    at ``walkers_per_s`` up to ``burst_walkers``.  Insufficient tokens
    raise :class:`AdmissionError` (named limit + value) and count in
    ``ServiceStats.stream_quota_rejections`` — quota is admission control,
    not silent deprioritization, so tenants see their back-pressure.
    """

    walkers_per_s: float
    burst_walkers: float


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Scheduling knobs of a :class:`StreamingSamplingService`.

    max_batch_window_ms: longest a deadline-less request waits for
    co-batching — the implied SLO.  Explicit ``deadline_ms`` overrides it
    per request (tighter OR looser: a bulk request with a loose deadline
    keeps accumulating cohort-mates past the window).
    slack_factor: launch a cohort when its most urgent member's remaining
    slack falls below ``slack_factor ×`` the estimated launch cost (the
    safety margin over EMA noise; 1.0 would aim to finish exactly at the
    deadline).
    launch_cost_prior_ms / launch_cost_alpha: initial estimate and EMA
    weight for per-cohort-key launch cost measurement.
    tenant_quotas: token buckets by tenant name; tenants without an entry
    (and requests without a tenant) are unmetered.
    batching: ``False`` launches every request immediately in its own
    cohort — the open-loop benchmark's launch-per-request baseline; results
    are bit-identical either way.
    """

    max_batch_window_ms: float = 20.0
    slack_factor: float = 2.0
    launch_cost_prior_ms: float = 25.0
    launch_cost_alpha: float = 0.25
    default_priority: Priority = Priority.STANDARD
    tenant_quotas: Mapping[str, TenantQuota] = dataclasses.field(
        default_factory=dict
    )
    batching: bool = True


class StreamFuture:
    """One streamed request's pending result.

    ``result(timeout)`` blocks for the :class:`RequestResult` (raising the
    launch error if the cohort failed); ``add_done_callback`` runs the
    callback with this future from the scheduler thread (or inline when
    already done).  After completion, ``latency`` holds the request's
    :class:`RequestLatency` record (also appended to
    ``ServiceStats.stream_latencies``).
    """

    def __init__(self, request_id: int, tier: Priority):
        self.request_id = request_id
        self.tier = tier
        self.latency: Optional[RequestLatency] = None
        self._event = threading.Event()
        self._result: Optional[RequestResult] = None
        self._exception: Optional[BaseException] = None
        self._callbacks: List[Callable[["StreamFuture"], None]] = []
        self._cb_lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not served within {timeout}s"
            )
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not served within {timeout}s"
            )
        return self._exception

    def add_done_callback(self, fn: Callable[["StreamFuture"], None]) -> None:
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _finish(
        self,
        result: Optional[RequestResult],
        exception: Optional[BaseException],
        latency: Optional[RequestLatency],
    ) -> None:
        self._result = result
        self._exception = exception
        self.latency = latency
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


@dataclasses.dataclass
class _Pending:
    """A submitted streaming request while it waits in a forming cohort."""

    req: SamplingRequest
    future: StreamFuture
    priority: Priority
    deadline: Optional[float]  # absolute clock time, None = window-bound
    submitted_at: float
    seq: int

    @property
    def effective_deadline(self) -> float:
        # resolved against the service window at evaluation time instead?
        # no: the window is a config constant, bind it at submit (cheaper,
        # and a mid-flight config swap must not reorder admitted requests)
        return self._eff

    def bind_window(self, window_s: float) -> "_Pending":
        self._eff = (
            self.deadline if self.deadline is not None
            else self.submitted_at + window_s
        )
        return self


class _TokenBucket:
    """Continuous-refill token bucket (tokens = walkers)."""

    def __init__(self, quota: TenantQuota, now: float):
        self.quota = quota
        self.level = float(quota.burst_walkers)
        self.last = now

    def try_take(self, tokens: float, now: float) -> bool:
        q = self.quota
        self.level = min(
            float(q.burst_walkers), self.level + q.walkers_per_s * (now - self.last)
        )
        self.last = now
        if tokens > self.level + 1e-9:
            return False
        self.level -= tokens
        return True


class StreamingSamplingService:
    """Always-on front door over a :class:`SamplingService` (any placement).

    >>> svc = SamplingService(graph, backend="reference")   # doctest: +SKIP
    >>> stream = StreamingSamplingService(svc)              # doctest: +SKIP
    >>> fut = stream.submit([0, 1], depth=8, spec=alg.deepwalk(),
    ...                     deadline_ms=50,
    ...                     priority=Priority.INTERACTIVE)  # doctest: +SKIP
    >>> fut.result().walks.shape                            # doctest: +SKIP
    (2, 9)

    The wrapped service's cohort machinery does all packing and launching;
    this class only decides *when* each forming cohort launches (module
    docstring / DESIGN.md §15).  With ``start=True`` (default) a daemon
    scheduler thread runs the loop; with ``start=False`` the caller drives
    it via :meth:`poll` / :meth:`flush` against the injected ``clock`` —
    the deterministic mode the policy tests and the parity harness use.

    The streaming front door owns the wrapped service's request-id and
    launch-key sequences while active; interleaving direct batch
    ``submit``/``drain`` calls on the same service is safe (ids stay
    unique) but their requests are invisible to the streaming scheduler.
    """

    def __init__(
        self,
        service: SamplingService,
        config: Optional[StreamConfig] = None,
        *,
        clock: Callable[[], float] = time.monotonic,
        start: bool = True,
    ):
        self._svc = service
        self.config = config or StreamConfig()
        self._clock = clock
        self._window_s = self.config.max_batch_window_ms / 1e3
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._launch_lock = threading.Lock()  # serializes device launches
        self._forming: Dict[tuple, List[_Pending]] = {}
        self._backlog_walkers = 0
        self._seq = 0
        self._cost_s: Dict[tuple, float] = {}
        self._buckets: Dict[str, _TokenBucket] = {}
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        """Start the scheduler thread (idempotent)."""
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._loop, name="stream-scheduler", daemon=True
        )
        self._thread.start()

    def close(self, flush: bool = True) -> None:
        """Stop admitting, optionally serve the backlog, stop the thread.

        With ``flush`` (default) every pending request still completes —
        an admitted request is never dropped by shutdown.  Without it,
        pending futures fail with :class:`DrainError`.
        """
        with self._wake:
            if self._closed:
                return
            self._closed = True
            self._wake.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if flush:
            self.flush()
        else:
            with self._lock:
                orphans = [p for ms in self._forming.values() for p in ms]
                self._forming.clear()
                self._backlog_walkers = 0
            for p in orphans:
                p.future._finish(
                    None,
                    DrainError(
                        f"request {p.req.request_id} cancelled: streaming "
                        f"service closed with flush=False", {},
                    ),
                    None,
                )

    def __enter__(self) -> "StreamingSamplingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close(flush=exc == (None, None, None))

    # -- intake ------------------------------------------------------------

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(m) for m in self._forming.values())

    @property
    def stats(self):
        return self._svc.stats

    def launch_cost_ms(self, spec: SamplingSpec, *, depth: int = 1,
                       width: int = 1) -> float:
        """Current launch-cost estimate for ``spec``'s cohort key at the
        bucketed geometry (the slack trigger's input) in milliseconds."""
        ck = self._cost_key(self._group_key(spec, depth, width))
        with self._lock:
            return self._cost_s.get(
                ck, self.config.launch_cost_prior_ms / 1e3
            ) * 1e3

    def submit(
        self,
        seeds,
        *,
        depth: int,
        spec: SamplingSpec,
        key: Optional[jax.Array] = None,
        deadline_ms: Optional[float] = None,
        priority: Optional[Priority] = None,
        tenant: Optional[str] = None,
    ) -> StreamFuture:
        """Admit one request into its forming cohort; returns its future.

        ``deadline_ms`` is the latency budget from NOW (absolute-ized
        against the service clock); omitted, the batching window is the
        implied SLO.  ``priority`` orders launches on deadline ties.
        ``tenant`` meters the request against its configured
        :class:`TenantQuota`.  Raises
        :class:`~repro.serve.queue.AdmissionError` (named limit + value)
        on malformed requests, backlog back-pressure, or quota exhaustion.
        """
        if priority is None:
            priority = self.config.default_priority
        with self._wake:
            if self._closed:
                raise AdmissionError("streaming service is closed")
            now = self._clock()
            req = self._svc._make_request(seeds, depth=depth, spec=spec, key=key)
            validate_request(req, self._svc.config)
            n_pending = sum(len(m) for m in self._forming.values())
            check_capacity(
                n_pending, self._backlog_walkers, req.num_walkers,
                self._svc.config,
            )
            self._check_quota(tenant, req.num_walkers, now)
            self._svc._next_id += 1  # all checks passed: consume the id
            gk = self._group_key(spec, req.depth, req.num_walkers)
            if not self.config.batching:
                gk = gk + (self._seq,)  # never co-batch: the baseline mode
            fut = StreamFuture(req.request_id, priority)
            pending = _Pending(
                req=req, future=fut, priority=priority,
                deadline=None if deadline_ms is None else now + deadline_ms / 1e3,
                submitted_at=now, seq=self._seq,
            ).bind_window(self._window_s)
            self._seq += 1
            self._forming.setdefault(gk, []).append(pending)
            self._backlog_walkers += req.num_walkers
            self._svc.stats.stream_requests += 1
            self._wake.notify_all()
            return fut

    def _check_quota(self, tenant: Optional[str], walkers: int, now: float) -> None:
        quota = (
            self.config.tenant_quotas.get(tenant) if tenant is not None else None
        )
        if quota is None:
            return
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = self._buckets[tenant] = _TokenBucket(quota, now)
        if not bucket.try_take(float(walkers), now):
            self._svc.stats.stream_quota_rejections += 1
            raise AdmissionError(
                f"tenant {tenant!r} over quota: request needs {walkers} "
                f"walkers, {bucket.level:.1f} available "
                f"(tenant_quotas[{tenant!r}].walkers_per_s="
                f"{quota.walkers_per_s}, burst_walkers={quota.burst_walkers})"
            )

    # -- cohort bookkeeping ------------------------------------------------

    def _group_key(self, spec: SamplingSpec, depth: int, width: int) -> tuple:
        """The forming-cohort key: identical grouping to
        ``RequestQueue.take_cohorts`` for this service's placement."""
        ck = cohort_key(spec)
        if self._svc.placement == "memory":
            cfg = self._svc.config
            return (
                ck,
                _pow2_bucket(depth, cfg.min_depth_bucket),
                _pow2_bucket(width, cfg.min_walker_bucket),
            )
        return (ck,)

    @staticmethod
    def _cost_key(group_key: tuple) -> tuple:
        # strip the batching=False uniquifier so the EMA still accumulates
        return group_key[:3] if len(group_key) > 3 else group_key

    def _evaluate(self, gk: tuple, members: List[_Pending], now: float):
        """(due, reason, launch_at, edf_sort_key) for one forming cohort.

        Per-member launch points: a deadline'd member must launch once its
        remaining slack shrinks to ``slack_factor ×`` the cohort key's
        measured launch cost (any later and the result lands past the
        deadline); a window-bound member launches when its batching window
        elapses (waiting is bounded by policy, not by a completion
        estimate).  The cohort launches at the earliest member's point.
        """
        cost = self._cost_s.get(
            self._cost_key(gk), self.config.launch_cost_prior_ms / 1e3
        )
        slack_lead = self.config.slack_factor * cost

        def launch_point(p: _Pending) -> float:
            if p.deadline is not None:
                return p.deadline - slack_lead
            return p.submitted_at + self._window_s

        urgent = min(members, key=launch_point)
        launch_at = launch_point(urgent)
        sort_key = (
            min(p.effective_deadline for p in members),
            min(p.priority for p in members),
            members[0].seq,
        )
        if not self.config.batching:
            return True, "immediate", launch_at, sort_key
        if len(members) >= self._svc.config.max_requests_per_launch:
            return True, "fill", launch_at, sort_key
        if now >= launch_at:
            reason = "slack" if urgent.deadline is not None else "window"
            return True, reason, launch_at, sort_key
        return False, "", launch_at, sort_key

    def _pick(self, now: float, due_only: bool = True):
        """Best launchable cohort under EDF (+priority, +FIFO), or None."""
        best = None
        for gk, members in self._forming.items():
            due, reason, _launch_at, sort_key = self._evaluate(gk, members, now)
            if due_only and not due:
                continue
            if best is None or sort_key < best[0]:
                best = (sort_key, gk, reason if due else "flush")
        return best

    def _next_launch_at(self, now: float) -> Optional[float]:
        ats = [
            self._evaluate(gk, members, now)[2]
            for gk, members in self._forming.items()
        ]
        return min(ats) if ats else None

    def _pop(self, gk: tuple, reason: str):
        """Remove a forming cohort and pack it at the batch path's geometry."""
        members = self._forming.pop(gk)
        self._backlog_walkers -= sum(p.req.num_walkers for p in members)
        reqs = tuple(p.req for p in members)
        if self._svc.placement == "memory":
            depth_b, width_b = gk[1], gk[2]
        else:
            cfg = self._svc.config
            depth_b = _pow2_bucket(max(r.depth for r in reqs), cfg.min_depth_bucket)
            width_b = max(r.num_walkers for r in reqs)
        cohort = Cohort(key=gk[0], requests=reqs, depth=depth_b, width=width_b)
        return cohort, members, reason

    # -- execution ---------------------------------------------------------

    def _execute(self, cohort: Cohort, members: List[_Pending], reason: str) -> None:
        """One cohort launch + per-request delivery and accounting."""
        out: Dict[int, RequestResult] = {}
        error: Optional[Exception] = None
        with self._launch_lock:
            t0 = self._clock()
            try:
                self._svc._run_cohort(cohort, out)
            except Exception as e:  # noqa: BLE001 - delivered via futures
                error = e
            t1 = self._clock()
        launch_ms = (t1 - t0) * 1e3
        stats = self._svc.stats
        deliveries = []
        with self._lock:
            stats.stream_launches += 1
            if error is None:
                ck = self._cost_key(
                    self._group_key(
                        cohort.requests[0].spec, cohort.depth, cohort.width
                    )
                )
                a = self.config.launch_cost_alpha
                old = self._cost_s.get(ck)
                measured = t1 - t0
                self._cost_s[ck] = (
                    measured if old is None else a * measured + (1 - a) * old
                )
            for p in members:
                rid = p.req.request_id
                result = out.get(rid)
                met = None
                if p.deadline is not None:
                    met = t1 <= p.deadline
                    if not met:
                        stats.stream_deadline_misses += 1
                lat = RequestLatency(
                    request_id=rid, tier=int(p.priority),
                    queue_ms=(t0 - p.submitted_at) * 1e3,
                    launch_ms=launch_ms,
                    total_ms=(t1 - p.submitted_at) * 1e3,
                    reason=reason, deadline_met=met,
                )
                stats.stream_latencies.append(lat)
                exc = None
                if result is None:
                    stats.stream_failed_requests += 1
                    exc = DrainError(
                        f"request {rid}: cohort launch failed "
                        f"({type(error).__name__ if error else 'missing result'}"
                        f": {error}); {len(out)}/{len(members)} cohort members "
                        f"completed before the failure",
                        dict(out),
                    )
                    exc.__cause__ = error
                deliveries.append((p.future, result, exc, lat))
        for fut, result, exc, lat in deliveries:
            fut._finish(result, exc, lat)

    def _launch_next(self, due_only: bool = True) -> bool:
        with self._lock:
            pick = self._pick(self._clock(), due_only=due_only)
            if pick is None:
                return False
            _, gk, reason = pick
            cohort, members, reason = self._pop(gk, reason)
        self._execute(cohort, members, reason)
        return True

    def poll(self) -> int:
        """Synchronously launch every currently-due cohort (EDF order);
        returns the number of launches.  The ``start=False`` driving mode —
        with an injected clock this makes the policy fully deterministic."""
        n = 0
        while self._launch_next(due_only=True):
            n += 1
        return n

    def flush(self) -> int:
        """Launch everything pending, due or not; returns launch count."""
        n = 0
        while self._launch_next(due_only=False):
            n += 1
        return n

    def _loop(self) -> None:
        while True:
            with self._wake:
                while not self._closed:
                    now = self._clock()
                    pick = self._pick(now, due_only=True)
                    if pick is not None:
                        break
                    nxt = self._next_launch_at(now)
                    if nxt is None:
                        self._wake.wait()
                    else:
                        # cap the sleep: launch-cost EMAs can move the due
                        # time earlier while we sleep
                        self._wake.wait(min(max(nxt - now, 1e-4), 0.05))
                if self._closed:
                    return  # close() flushes the backlog synchronously
                _, gk, reason = pick
                cohort, members, reason = self._pop(gk, reason)
            self._execute(cohort, members, reason)


def percentile(samples, q: float) -> float:
    """Latency percentile helper (``q`` in [0, 100]) used by the open-loop
    benchmark and the streaming example; NaN on empty input."""
    if not len(samples):
        return float("nan")
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))
