"""Walk-engine benchmark: transition programs on the fast path → BENCH_walk.json.

Sweeps {deepwalk, node2vec, mhrw, rw_restart} × {reference, pallas} ×
{in-memory, out-of-memory} on the pl50k benchmark graph, plus the
forced-opaque node2vec configuration (transition program stripped, i.e. the
pre-transition-program dense full-context gather) so the headline number —
the bucketed dynamic-bias path vs the dense gather it replaced — is measured
PR-over-PR.  On CPU the Pallas route runs in interpret mode — expect it to
LOSE there; the cross-cutting numbers are reference-vs-reference (bucketed
vs gather) on any host and the kernel ratio on TPU.

Usage:  PYTHONPATH=src python benchmarks/bench_walk.py [--iters 3]
(also exposed as ``run()`` rows through benchmarks/run.py)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import BENCH_GRAPHS, row, timeit  # noqa: E402

from repro.core import algorithms as alg  # noqa: E402
from repro.core.engine import random_walk  # noqa: E402
from repro.core.oom import oom_random_walk  # noqa: E402
from repro.graph.partition import partition_by_vertex_range  # noqa: E402

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_walk.json"

GRAPH = "pl50k"
WALKERS = 1024
DEPTH = 8
OOM_PARTS = 4
OOM_CHUNK = 1024
KEY = jax.random.PRNGKey(0)


def _specs(g):
    n2v = alg.node2vec()
    return {
        "deepwalk": alg.deepwalk(),
        "node2vec": n2v,
        # the pre-PR dense full-context gather: same hooks, program stripped
        "node2vec_gather": dataclasses.replace(n2v, transition=None),
        "mhrw": alg.metropolis_hastings_walk(),
        "rw_restart": alg.random_walk_with_restart(0.15),
    }


def bench_inmem(g, spec, backend, iters):
    seeds = jax.random.randint(KEY, (WALKERS,), 0, g.num_vertices)
    md = g.max_degree()

    def fn(graph, seeds, key):
        return random_walk(
            graph, seeds, key, depth=DEPTH, spec=spec, max_degree=md, backend=backend
        ).walks

    return timeit(fn, g, seeds, KEY, warmup=1, iters=iters)


def bench_oom(g, spec, backend, iters):
    parts = partition_by_vertex_range(g, OOM_PARTS)
    seeds = np.random.default_rng(0).integers(0, g.num_vertices, WALKERS)
    md = g.max_degree()

    def fn():
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, KEY, depth=DEPTH, spec=spec,
            max_degree=md, memory_capacity=2, chunk=OOM_CHUNK, backend=backend)
        return walks

    # oom_random_walk blocks internally (host scheduling loop)
    return timeit(lambda: jax.numpy.asarray(fn()), warmup=1, iters=iters)


def run(iters: int = 3):
    g = BENCH_GRAPHS[GRAPH]()
    on_tpu = jax.default_backend() == "tpu"
    results = []
    for name, spec in _specs(g).items():
        for backend in ("reference", "pallas"):
            for mode, bench in (("inmem", bench_inmem), ("oom", bench_oom)):
                if name == "node2vec_gather" and mode == "oom":
                    continue  # the dense OOM gather at pl50k degrees is pathological
                if backend == "pallas" and mode == "oom" and not on_tpu:
                    continue  # interpret-mode kernels in the drain loop: minutes
                secs = bench(g, spec, backend, iters)
                results.append({
                    "graph": GRAPH, "algo": name, "mode": mode,
                    "backend": backend, "seconds": secs,
                })
                yield row(f"walk_{name}_{mode}_{backend}", secs * 1e6,
                          f"walkers={WALKERS};depth={DEPTH}")

    by = {(r["algo"], r["mode"], r["backend"]): r["seconds"] for r in results}
    speedup = by[("node2vec_gather", "inmem", "reference")] / by[("node2vec", "inmem", "reference")]
    results.append({
        "graph": GRAPH, "algo": "node2vec", "mode": "inmem",
        "derived": "bucketed_vs_gather_speedup_reference", "speedup": speedup,
    })
    yield row("walk_node2vec_bucketed_vs_gather", 0.0, f"speedup={speedup:.2f}x")

    OUT_PATH.write_text(json.dumps({
        # shared benchmark-JSON schema (DESIGN.md §9): diffable PR-over-PR
        "bench": "walk",
        "device": jax.default_backend(),
        "pallas_interpret": not on_tpu,
        "graph": GRAPH, "walkers": WALKERS, "depth": DEPTH,
        "results": results,
    }, indent=2))
    yield row("walk_json", 0.0, str(OUT_PATH.name))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(args.iters):
        print(r, flush=True)


if __name__ == "__main__":
    main()
