"""Shared benchmark utilities: timing, graphs, CSV rows."""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.graph import erdos_renyi_graph, powerlaw_graph, rmat_graph

# offline stand-ins for the paper's SNAP graphs (Table II regimes):
#   scale-free social-network-like (OR/LJ) -> rmat
#   low-degree web/citation (WG/CP/AM)     -> powerlaw sparse
#   uniform control                        -> erdos-renyi
BENCH_GRAPHS = {
    "rmat14": lambda: rmat_graph(14, edge_factor=16, seed=7, weighted=True),
    "pl50k": lambda: powerlaw_graph(50_000, exponent=2.1, seed=7, weighted=True),
    "er50k": lambda: erdos_renyi_graph(50_000, avg_degree=8, seed=7, weighted=True),
}


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds; blocks on all jax outputs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
