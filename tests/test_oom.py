"""Out-of-memory partition scheduler (paper §V)."""
import jax
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.oom import oom_random_walk
from repro.graph import powerlaw_graph
from repro.graph.partition import partition_by_vertex_range, partition_of


@pytest.fixture(scope="module")
def setup():
    g = powerlaw_graph(512, seed=3, weighted=True)
    parts = partition_by_vertex_range(g, 4)
    seeds = np.random.default_rng(0).integers(0, 512, 96)
    return g, parts, seeds


class TestPartitioning:
    def test_ranges_cover_all_vertices(self, setup):
        g, parts, _ = setup
        assert parts[0].vertex_lo == 0
        assert parts[-1].vertex_hi == g.num_vertices
        for a, b in zip(parts[:-1], parts[1:]):
            assert a.vertex_hi == b.vertex_lo

    def test_all_edges_of_vertex_in_one_partition(self, setup):
        """The paper's core partitioning requirement (§V-A)."""
        g, parts, _ = setup
        ip = np.asarray(g.indptr)
        for p in parts:
            expect = ip[p.vertex_hi] - ip[p.vertex_lo]
            assert p.num_edges == expect

    def test_partition_of_constant_time_lookup(self, setup):
        g, parts, _ = setup
        v = np.arange(g.num_vertices)
        pid = partition_of(v, g.num_vertices, 4)
        for p in parts:
            assert (pid[p.vertex_lo : p.vertex_hi] == p.pid).all()

    def test_device_csr_matches_global(self, setup):
        g, parts, _ = setup
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        dev = parts[1].to_device_csr(g.num_vertices)
        dip, dind = np.asarray(dev.indptr), np.asarray(dev.indices)
        for v in range(parts[1].vertex_lo, parts[1].vertex_hi):
            np.testing.assert_array_equal(
                dind[dip[v] : dip[v + 1]], ind[ip[v] : ip[v + 1]]
            )


class TestOOMWalk:
    def test_walks_valid(self, setup):
        g, parts, seeds = setup
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        walks, stats = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=8,
            spec=alg.biased_random_walk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128)
        assert walks.shape == (96, 9)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert b in ind[ip[a] : ip[a + 1]]
        assert stats.sampled_edges > 0
        assert stats.partition_transfers >= 2

    def test_batching_reduces_kernel_launches(self, setup):
        """Paper Fig. 13: batched multi-instance vs per-instance."""
        g, parts, seeds = setup
        _, s_batched = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=128)
        _, s_single = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=128,
            batched=False)
        assert s_batched.kernel_launches < s_single.kernel_launches / 2

    def test_workload_aware_not_more_transfers(self, setup):
        """Paper Fig. 15: workload-aware scheduling cuts transfers."""
        g, parts8 = setup[0], partition_by_vertex_range(setup[0], 8)
        seeds = setup[2]
        _, s_ws = oom_random_walk(
            parts8, g.num_vertices, seeds, jax.random.PRNGKey(1), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128, workload_aware=True)
        _, s_rr = oom_random_walk(
            parts8, g.num_vertices, seeds, jax.random.PRNGKey(1), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128, workload_aware=False, balance=False)
        assert s_ws.partition_transfers <= s_rr.partition_transfers

    def test_results_independent_of_scheduling(self, setup):
        """Correctness argument from the paper (§V-B): out-of-order partition
        scheduling must not change which seeds produce walks (same seeds,
        same depth coverage)."""
        g, parts, seeds = setup
        w1, _ = oom_random_walk(parts, g.num_vertices, seeds, jax.random.PRNGKey(2),
                                depth=5, spec=alg.deepwalk(), max_degree=g.max_degree(),
                                workload_aware=True, chunk=64)
        w2, _ = oom_random_walk(parts, g.num_vertices, seeds, jax.random.PRNGKey(2),
                                depth=5, spec=alg.deepwalk(), max_degree=g.max_degree(),
                                workload_aware=False, chunk=64)
        np.testing.assert_array_equal(w1[:, 0], w2[:, 0])
        # same number of completed steps per instance (dead ends aside, all
        # should reach full depth on this connected-ish graph)
        assert (w1 >= 0).sum() > 0.9 * w1.size
        assert (w2 >= 0).sum() > 0.9 * w2.size
