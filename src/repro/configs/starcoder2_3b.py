"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173].

Assigned: 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
Plain GELU MLP (no GLU), sliding-window-free full attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    pattern=("global",),
    activation="gelu",
    glu=False,
    tie_embeddings=True,
    optimizer="adamw",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("global",),
    activation="gelu",
    glu=False,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
