"""Mixture-of-Experts with sort-based capacity dispatch (EP over `model`).

Dispatch layout (DESIGN.md §5): tokens stay sharded over the fsdp axes as
groups ``G`` (= batch rows); experts shard over ``model``.  Every device
already holds (its token groups × its expert shard), so dispatch is a *local
gather* and combine a *local scatter-add* — no all-to-all, no (T, E, C)
one-hot monsters (the einsum dispatch used in early Switch implementations
materializes O(T·E·C) — measured 415GB/device on arctic-480b; EXPERIMENTS.md
§Perf iteration 0).  Per-(group, expert) capacity drops overflow tokens.

Router modes:
  - ``topk``    — deterministic top-k (standard).
  - ``sampled`` — C-SAW integration (DESIGN.md §4): experts sampled *without
    replacement* with router probabilities as biases (Gumbel top-k — the
    paper's selection semantics; exploration-friendly routing).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ACTIVATIONS, ParamDef, ashard


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, e), ("embed", None)),
        "wi": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.glu:
        defs["wg"] = ParamDef((e, d, f), ("experts", "embed", "mlp"))
    return defs


def _route(params, cfg: ModelConfig, x: jax.Array, rng: jax.Array | None):
    """x: (..., D). Returns (gates, idx, probs) with (..., k) leading dims."""
    logits = jnp.einsum("...d,de->...e", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.num_experts_per_tok
    if cfg.router_mode == "sampled" and rng is not None:
        # C-SAW: weighted sampling without replacement, biases = router probs.
        g = jax.random.gumbel(rng, probs.shape, dtype=jnp.float32)
        keys_ = jnp.log(jnp.maximum(probs, 1e-20)) + g
        _, idx = jax.lax.top_k(keys_, k)
        gates = jnp.take_along_axis(probs, idx, axis=-1)
    else:
        gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def moe_apply(
    params: dict, cfg: ModelConfig, x: jax.Array, rng: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Groups = batch rows (B stays sharded)."""
    g_dim, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    tk = s * k
    capacity = max(int(s * k / e * cfg.capacity_factor), 4)

    gates, idx, probs = _route(params, cfg, x, rng)  # (G, S, k)

    # ---- sort-based dispatch plan, per group --------------------------------
    flat_e = idx.reshape(g_dim, tk)  # expert of each (token, choice)
    flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :]
    flat_gate = gates.reshape(g_dim, tk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_tok = jnp.take_along_axis(jnp.broadcast_to(flat_tok, (g_dim, tk)), order, axis=-1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=-1)
    # rank within expert segment: arange - running start-of-segment
    ar = jnp.arange(tk, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((g_dim, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=-1
    )
    run_start = jax.lax.cummax(jnp.where(is_start, ar, 0), axis=1)
    rank = ar - run_start
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity)  # capacity = out-of-bounds -> drop

    garange = jnp.arange(g_dim)[:, None]
    grid_tok = jnp.full((g_dim, e, capacity), s, jnp.int32)  # s = dummy row
    grid_tok = grid_tok.at[garange, sorted_e, slot].set(sorted_tok, mode="drop")
    grid_gate = jnp.zeros((g_dim, e, capacity), jnp.float32)
    grid_gate = grid_gate.at[garange, sorted_e, slot].set(sorted_gate, mode="drop")

    # ---- expert compute (fully local in the (data, model) grid) -------------
    xp = ashard(
        jnp.concatenate([x, jnp.zeros((g_dim, 1, d), x.dtype)], axis=1),
        "batch", None, None,
    )  # dummy row at index s
    expert_in = jnp.take_along_axis(
        xp[:, :, None, :], grid_tok.reshape(g_dim, -1)[:, :, None, None], axis=1
    ).reshape(g_dim, e, capacity, d)
    expert_in = ashard(expert_in, "batch", "model", None, None)
    act = ACTIVATIONS[cfg.activation]
    h = ashard(jnp.einsum("gecd,edf->gecf", expert_in, params["wi"]), "batch", "model", None, None)
    if cfg.glu:
        gg = ashard(jnp.einsum("gecd,edf->gecf", expert_in, params["wg"]), "batch", "model", None, None)
        h = act(gg) * h
    else:
        h = act(h)
    expert_out = ashard(jnp.einsum("gecf,efd->gecd", h, params["wo"]), "batch", "model", None, None)
    expert_out = (expert_out * grid_gate[..., None]).astype(x.dtype)

    # ---- combine: scatter-add back to token rows (bf16, sharded acc) --------
    y = ashard(jnp.zeros((g_dim, s + 1, d), x.dtype), "batch", None, None)
    y = y.at[garange[:, :, None], grid_tok, :].add(expert_out, mode="drop")[:, :s]
    y = ashard(y, "batch", None, None)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(me * ce) * e
    return y.astype(x.dtype), aux
