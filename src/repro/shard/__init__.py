"""Device-mesh sharded sampling: owner-routed frontier exchange (§V-D).

Range-sharded graphs as a first-class execution target: each mesh device
holds one compact partition CSR (HBM ∝ 1/D) and walkers are ROUTED to the
shard owning their frontier vertex each step — fixed-capacity per-
destination compaction, one ``all_to_all``, overflow deferred rather than
dropped.  Flat- and window-bias transition programs reproduce single-device
``engine.random_walk`` bit for bit on both backends; see DESIGN.md §12 and
``docs/api.md`` for the contract.
"""
from repro.shard.exchange import (
    ShardQueue,
    all_to_all_fields,
    make_queue,
    queue_pop,
    queue_push,
    route_by_owner,
)
from repro.shard.walk import (
    replicated_psum_walk,
    shard_graph_for_mesh,
    sharded_random_walk,
)

__all__ = [
    "ShardQueue",
    "all_to_all_fields",
    "make_queue",
    "queue_pop",
    "queue_push",
    "replicated_psum_walk",
    "route_by_owner",
    "shard_graph_for_mesh",
    "sharded_random_walk",
]
