"""Pure-jnp oracles for the Pallas kernels.

Each oracle consumes the *same* pre-generated random budget as its kernel and
performs bit-identical math, so tests can ``assert_allclose`` (exact for the
integer outputs) across shapes and dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _onehot_gather(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather along the last axis via a one-hot contraction (MXU-friendly —
    mirrors the kernel exactly, including its numerics)."""
    oh = jax.nn.one_hot(idx, table.shape[-1], dtype=table.dtype)
    return jnp.einsum("...kp,...p->...k", oh, table)


def its_select_ref(biases: jax.Array, rands: jax.Array) -> jax.Array:
    """ITS + bipartite-region-search without replacement (oracle).

    biases: (I, P) float; rands: (I, ITERS, K) float in [0,1).
    Returns selected indices (I, K) int32, -1 where the random budget was
    exhausted or no candidate remains.
    """
    i_dim, p = biases.shape
    iters, k = rands.shape[1], rands.shape[2]
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    sums = jnp.cumsum(b, axis=-1)
    total = jnp.maximum(sums[:, -1:], _EPS)
    ctps = sums / total
    lower = jnp.concatenate([jnp.zeros_like(ctps[:, :1]), ctps[:, :-1]], axis=-1)
    navail = jnp.sum(b > 0, axis=-1)
    want = jnp.minimum(navail, k)

    def search(r):
        idx = jnp.sum(ctps[:, None, :] <= r[:, :, None], axis=-1)
        return jnp.clip(idx, 0, p - 1).astype(jnp.int32)

    def body(it, carry):
        done, out, selmask = carry
        r1 = rands[:, it, :]
        idx1 = search(r1)
        hit1 = _onehot_gather(selmask.astype(jnp.float32), idx1) > 0.5
        l = _onehot_gather(lower, idx1)
        h = _onehot_gather(ctps, idx1)
        delta = h - l
        r2 = r1 * (1.0 - delta)
        r2 = jnp.where(r2 < l, r2, r2 + delta)
        r2 = jnp.clip(r2, 0.0, 1.0 - _EPS)
        idx2 = search(r2)
        hit2 = _onehot_gather(selmask.astype(jnp.float32), idx2) > 0.5
        cand = jnp.where(hit1, idx2, idx1)
        ok = ~done & ~jnp.where(hit1, hit2, hit1)
        ok = ok & (_onehot_gather(b, cand) > 0)
        eq = cand[:, :, None] == cand[:, None, :]
        both = ok[:, :, None] & ok[:, None, :]
        beaten = jnp.any(eq & both & jnp.tril(jnp.ones((k, k), bool), -1), axis=-1)
        win = ok & ~beaten
        out = jnp.where(win, cand, out)
        oh = jax.nn.one_hot(jnp.where(win, cand, 0), p, dtype=bool) & win[..., None]
        selmask = selmask | jnp.any(oh, axis=-2)
        done = done | win
        got = jnp.sum(done, axis=-1)
        done = done | ((got >= want)[..., None] & (jnp.arange(k) >= want[..., None]))
        return done, out, selmask

    done0 = jnp.arange(k)[None, :] >= want[:, None]
    out0 = jnp.full((i_dim, k), -1, jnp.int32)
    sel0 = jnp.zeros((i_dim, p), bool)
    _, out, _ = jax.lax.fori_loop(0, iters, body, (done0, out0, sel0))
    return out


def _block_window(starts: jax.Array, degs: jax.Array, seg: int, width: int):
    """Block-aligned window coordinates of each walker's CSR segment.

    The kernel DMAs the two consecutive ``seg``-blocks containing a walker's
    row (``walk_step_pallas``); its window starts at ``blk0 = start//seg*seg``
    and the row occupies offsets ``[local, local+deg)`` with
    ``local = start % seg``.  Returns ``(local, blk0, offs, mask)`` with
    ``offs`` of length ``width`` (``2*seg`` for the kernel's full window; a
    truncated tail never changes any cumsum prefix)."""
    local = starts % seg
    blk0 = starts // seg * seg
    offs = jnp.arange(width, dtype=jnp.int32)
    mask = (offs >= local[..., None]) & (offs < (local + degs)[..., None])
    return local, blk0, offs, mask


def _window_pick(
    local: jax.Array,
    blk0: jax.Array,
    degs: jax.Array,
    mask: jax.Array,
    wts: jax.Array,
    rand: jax.Array,
    inds_p: jax.Array,
) -> jax.Array:
    """Masked-cumsum ITS pick over block-aligned windows — the kernel's exact
    arithmetic (DESIGN.md §6).  XLA's cumsum is position-indexed (prefix ``i``
    combines elements in a tree fixed by ``i`` alone), so ``wts`` must sit at
    the kernel's window offsets; then reference and Pallas agree bit-for-bit.
    The selected id is gathered directly instead of through the kernel's
    float32 one-hot reduction (identical for ids < 2^24, i.e. any graph this
    repo can hold in f32 bias arrays)."""
    cum = jnp.cumsum(wts, axis=-1)
    total = cum[..., -1]
    target = rand * total
    pick = jnp.sum(((cum <= target[..., None]) & mask).astype(jnp.int32), axis=-1)
    pick = jnp.minimum(local + pick, local + jnp.maximum(degs - 1, 0))
    cand = inds_p[blk0 + pick]
    dead = (degs <= 0) | (total <= _EPS)
    return jnp.where(dead, -1, cand)


def walk_step_block_ref(
    starts: jax.Array,
    degs: jax.Array,
    inds_p: jax.Array,
    bias_p: jax.Array,
    rand: jax.Array,
    *,
    seg: int,
    width: int | None = None,
) -> jax.Array:
    """Pure-jnp mirror of one flat-bias ``walk_step_pallas`` cohort.

    ``inds_p``/``bias_p`` are the SAME padded flat CSR arrays the kernel
    DMAs (``pad_csr_for_kernel``); bias is gathered from the flat array at
    the window offsets.  ``width`` defaults to the kernel's full ``2*seg``
    window; callers that know the true max row degree may truncate the tail
    (``seg + min(seg, max_degree)``) without changing any prefix."""
    width = 2 * seg if width is None else width
    local, blk0, _, mask = _block_window(starts, degs, seg, width)
    win = blk0[..., None] + jnp.arange(width, dtype=jnp.int32)
    wts = jnp.where(mask, bias_p[win], 0.0)
    return _window_pick(local, blk0, degs, mask, wts, rand, inds_p)


def walk_step_window_block_ref(
    starts: jax.Array,
    degs: jax.Array,
    inds_p: jax.Array,
    bias_win: jax.Array,
    rand: jax.Array,
    *,
    seg: int,
) -> jax.Array:
    """Pure-jnp mirror of one window-bias ``walk_step_window_pallas`` cohort.

    ``bias_win`` is the per-walker ``(W, 2*seg)`` bias evaluated on the
    block-aligned edge window (``core.backend.walk_step_bucketed_window``
    computes it ONCE, in shared jnp, for both backends — so cross-backend
    parity reduces to the pick arithmetic, which this mirrors exactly)."""
    local, blk0, _, mask = _block_window(starts, degs, seg, bias_win.shape[-1])
    wts = jnp.where(mask, bias_win, 0.0)
    return _window_pick(local, blk0, degs, mask, wts, rand, inds_p)


def alias_step_block_ref(
    starts: jax.Array,
    degs: jax.Array,
    inds_p: jax.Array,
    prob_p: jax.Array,
    alias_p: jax.Array,
    rand: jax.Array,
    *,
    seg: int,
) -> jax.Array:
    """Pure-jnp mirror of one ``alias_step_pallas`` cohort (DESIGN.md §13).

    ``inds_p``/``prob_p``/``alias_p`` are the SAME padded flat arrays the
    kernel DMAs; one uniform splits into slot ``⌊u·deg⌋`` and coin, the coin
    routes through the prebuilt redirect.  The kernel's f32 one-hot gathers
    are exact (single nonzero term, values < 2^24), so a direct gather is
    bit-identical."""
    deg_eff = jnp.minimum(degs, seg)  # absorbed oversized rows truncate
    local = starts % seg
    blk0 = starts // seg * seg
    u = rand * deg_eff.astype(jnp.float32)
    slot = jnp.minimum(u.astype(jnp.int32), jnp.maximum(deg_eff - 1, 0))
    frac = u - slot.astype(jnp.float32)
    pos = blk0 + local + slot
    pval = prob_p[pos]
    aval = alias_p[pos]
    chosen = jnp.clip(
        jnp.where(frac < pval, slot, aval), 0, jnp.maximum(deg_eff - 1, 0)
    )
    nxt = inds_p[blk0 + local + chosen]
    dead = (degs <= 0) | (aval < 0)  # zero-total rows carry alias = -1
    return jnp.where(dead, -1, nxt).astype(jnp.int32)


def reject_step_block_ref(
    starts: jax.Array,
    degs: jax.Array,
    inds_p: jax.Array,
    bias_p: jax.Array,
    row_max: jax.Array,
    rej: jax.Array,
    *,
    seg: int,
) -> jax.Array:
    """Pure-jnp mirror of one ``reject_step_pallas`` cohort (DESIGN.md §13).

    ``rej`` is the (W, iters, 2) counted budget from
    ``core.select.rejection_randoms``: round ``t`` proposes
    ``slot = ⌊r_slot·deg⌋`` and accepts iff ``r_acc·row_max < bias[slot]``;
    first acceptance wins, an exhausted budget keeps the last proposal
    carrying mass — exactly the kernel's statically-unrolled loop."""
    iters = rej.shape[1]
    deg_eff = jnp.minimum(degs, seg)
    degf = deg_eff.astype(jnp.float32)
    local = starts % seg
    blk0 = starts // seg * seg
    chosen = jnp.full_like(starts, -1)
    done = jnp.zeros(starts.shape, bool)
    last = jnp.zeros_like(starts)
    last_b = jnp.zeros(starts.shape, jnp.float32)
    for t in range(iters):
        slot = jnp.minimum(
            (rej[:, t, 0] * degf).astype(jnp.int32), jnp.maximum(deg_eff - 1, 0)
        )
        bval = bias_p[blk0 + local + slot]
        acc = rej[:, t, 1] * row_max < bval
        chosen = jnp.where(~done & acc, slot, chosen)
        last, last_b = slot, bval
        done = done | acc
    chosen = jnp.where(done, chosen, jnp.where(last_b > 0, last, -1))
    nxt = inds_p[blk0 + local + jnp.maximum(chosen, 0)]
    dead = (degs <= 0) | (row_max <= 0) | (chosen < 0)
    return jnp.where(dead, -1, nxt).astype(jnp.int32)


def walk_step_ref(
    starts: jax.Array,
    degs: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    rand: jax.Array,
    max_seg: int,
) -> jax.Array:
    """One weighted ITS walk step per walker (oracle for walk_step kernel).

    starts/degs: (W,) row start offsets and degrees (deg <= max_seg);
    indices/weights: flat CSR arrays; rand: (W,) uniforms.
    Returns next vertex (W,) int32, -1 for dead ends.
    """
    offs = jnp.arange(max_seg, dtype=jnp.int32)
    idx = starts[:, None] + offs[None, :]
    mask = offs[None, :] < degs[:, None]
    w = jnp.where(mask, weights[jnp.where(mask, idx, 0)], 0.0)
    cum = jnp.cumsum(w, axis=-1)
    total = cum[:, -1]
    target = rand * total
    pick = jnp.sum((cum <= target[:, None]) & mask, axis=-1)
    pick = jnp.minimum(pick, jnp.maximum(degs - 1, 0))
    nxt = indices[jnp.clip(starts + pick, 0, indices.shape[0] - 1)]
    return jnp.where((degs > 0) & (total > 0), nxt, -1).astype(jnp.int32)
