"""Training substrate: optimizers, checkpointing, fault tolerance, pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StepMonitor, largest_mesh_shape, run_with_recovery
from repro.train.optimizer import OptConfig, opt_init, opt_update
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


class TestOptimizers:
    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_quadratic_convergence(self, kind):
        """Optimizer drives a quadratic toward its minimum."""
        target = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.ones((4, 200)) * 0.5}
        params = {"w": jnp.zeros(3), "b": jnp.zeros((4, 200))}
        cfg = OptConfig(kind=kind, lr=0.05, weight_decay=0.0, warmup_steps=1,
                        min_dim_factored=4)
        state = opt_init(cfg, params)
        loss = lambda p: sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)
        l0 = float(loss(params))
        for i in range(200):
            grads = jax.grad(loss)(params)
            params, state, _ = opt_update(cfg, grads, state, params, jnp.array(i))
        assert float(loss(params)) < 0.05 * l0

    def test_adafactor_state_is_factored(self):
        params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
        cfg = OptConfig(kind="adafactor")
        state = opt_init(cfg, params)
        assert set(state["v"]["big"].keys()) == {"vr", "vc"}
        assert state["v"]["big"]["vr"].shape == (256,)
        assert state["v"]["big"]["vc"].shape == (512,)
        assert set(state["v"]["small"].keys()) == {"v"}

    def test_grad_clip(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptConfig(kind="adamw", grad_clip=1.0, lr=1.0, warmup_steps=1)
        state = opt_init(cfg, params)
        huge = {"w": jnp.full(4, 1e6)}
        new, _, gnorm = opt_update(cfg, huge, state, params, jnp.array(0))
        assert float(gnorm) > 1e5
        assert np.abs(np.asarray(new["w"])).max() < 10.0


class TestTrainingLoop:
    def test_loss_decreases_on_learnable_data(self):
        cfg = get_smoke_config("internlm2-1.8b")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ocfg = OptConfig(kind="adamw", lr=3e-3, warmup_steps=2)
        params = init_params(KEY, cfg)
        opt_state = opt_init(ocfg, params)
        step_fn, _ = make_train_step(cfg, ocfg, mesh)
        # learnable corpus: fixed repeating pattern
        base = np.arange(33) % 7 + 1
        batch = {
            "tokens": jnp.asarray(np.tile(base[:-1], (4, 1)), jnp.int32),
            "labels": jnp.asarray(np.tile(base[1:], (4, 1)), jnp.int32),
        }
        step = jnp.zeros((), jnp.int32)
        losses = []
        for _ in range(20):
            params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < 0.5 * losses[0], losses


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, fingerprint="test")
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
        mgr.save(10, tree)
        restored, manifest = mgr.restore(tree)
        assert manifest["step"] == 10
        for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(1000.0)}
        mgr.save_async(5, tree)
        mgr.wait()
        restored, m = mgr.restore(tree)
        assert m["step"] == 5

    def test_atomicity_no_tmp_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, {"a": jnp.zeros(2)})
        names = os.listdir(tmp_path)
        assert all(not n.endswith(".tmp") for n in names)

    def test_fingerprint_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, fingerprint="cfgA")
        mgr.save(1, {"a": jnp.zeros(2)})
        mgr2 = CheckpointManager(str(tmp_path), keep=3, fingerprint="cfgB")
        with pytest.raises(ValueError):
            mgr2.restore({"a": jnp.zeros(2)})

    def test_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        for s in (3, 7, 11):
            mgr.save(s, {"a": jnp.full(2, float(s))})
        restored, m = mgr.restore({"a": jnp.zeros(2)})
        assert m["step"] == 11
        assert float(restored["a"][0]) == 11.0


class TestFaultTolerance:
    def test_straggler_detection(self):
        mon = StepMonitor(deadline_factor=3.0)
        for i in range(10):
            assert not mon.observe(i, 1.0)
        assert mon.observe(10, 10.0)  # 10x median
        assert mon.straggler_steps == [10]

    def test_recovery_replays_from_checkpoint(self):
        calls = {"n": 0}

        def step_fn(a, b, batch):
            return a + batch, b, {"loss": 0.0}

        def fail_first(attempt):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("injected node failure")

        state, metrics, attempts = run_with_recovery(
            step_fn, (1, 2), 10,
            restore_fn=lambda: (100, 200),
            fail_injector=fail_first,
        )
        assert attempts == 1
        assert state == (110, 200)  # restored state was used

    def test_recovery_gives_up(self):
        def always_fail(attempt):
            raise RuntimeError("down")
        with pytest.raises(RuntimeError):
            run_with_recovery(lambda *a: a, (1,), 2,
                              restore_fn=lambda: (1,), max_retries=1,
                              fail_injector=always_fail)

    def test_largest_mesh_shape(self):
        assert largest_mesh_shape(512, 16) == (32, 16)
        assert largest_mesh_shape(496, 16) == (31, 16)  # 496 = 31×16
        assert largest_mesh_shape(508, 16) == (127, 4)  # lost nodes: shrink TP
        assert largest_mesh_shape(13, 4) == (13, 1)


class TestPipeline:
    def test_deterministic_by_cursor(self):
        p1 = TokenPipeline(100, 4, 16, seed=3)
        p2 = TokenPipeline(100, 4, 16, seed=3)
        b1, b2 = p1.next(), p2.next()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_state_restore_resumes_stream(self):
        p1 = TokenPipeline(100, 4, 16, seed=3)
        for _ in range(5):
            p1.next()
        state = p1.state_dict()
        expected = p1.next()
        p2 = TokenPipeline(100, 4, 16, seed=3)
        p2.load_state_dict(state)
        got = p2.next()
        np.testing.assert_array_equal(expected["tokens"], got["tokens"])

    def test_labels_shifted(self):
        corpus = np.tile(np.arange(17)[None], (8, 1))
        p = TokenPipeline(100, 4, 16, corpus=corpus)
        b = p.next()
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding(self):
        c = np.arange(8 * 17).reshape(8, 17) % 97
        p0 = TokenPipeline(100, 4, 16, corpus=c, host_index=0, host_count=2)
        p1 = TokenPipeline(100, 4, 16, corpus=c, host_index=1, host_count=2)
        b0, b1 = p0.next(), p1.next()
        assert b0["tokens"].shape == (2, 16)
        assert not np.array_equal(b0["tokens"], b1["tokens"])
