"""Pallas TPU kernel: fused random-walk step (segment DMA + ITS draw).

One grid step advances one walker: the walker's CSR neighbor segment is
DMA'd into VMEM by BlockSpec index_maps driven by scalar-prefetched row
starts (the TPU analogue of the paper's coalesced warp loads), then the
weighted ITS draw happens entirely in VMEM.

Degree bucketing (DESIGN.md §6): segments must satisfy ``deg <= max_seg``;
the engine routes larger rows through ``select.walk_transition_chunked``.
A segment can straddle a ``max_seg`` block boundary, so the index_maps pull
TWO consecutive blocks (same input bound twice with maps ``blk`` and
``blk+1``) and the kernel offsets into their concatenation.  Edge arrays must
be padded with one extra trailing block so ``blk+1`` always exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.its_select import resolve_interpret

_EPS = 1e-12


def _walk_step_kernel(
    starts_ref,  # scalar-prefetch (W,)
    degs_ref,  # scalar-prefetch (W,)
    rand_ref,  # (1,) this walker's uniform
    idx_lo_ref,  # (max_seg,) neighbor-id block containing `start`
    idx_hi_ref,  # (max_seg,) following block
    w_lo_ref,  # (max_seg,) weight blocks
    w_hi_ref,
    out_ref,  # (1,) next vertex
    *,
    max_seg: int,
):
    w = pl.program_id(0)
    start = starts_ref[w]
    deg = degs_ref[w]
    local = start % max_seg  # offset inside the 2-block window
    offs = jax.lax.broadcasted_iota(jnp.int32, (2 * max_seg,), 0)
    mask = (offs >= local) & (offs < local + deg)
    wts = jnp.where(mask, jnp.concatenate([w_lo_ref[...], w_hi_ref[...]]), 0.0)
    cum = jnp.cumsum(wts)
    total = cum[-1]
    target = rand_ref[0] * total
    # index of the edge whose cumulative bias crosses target
    pick = jnp.sum(((cum <= target) & mask).astype(jnp.int32))
    pick = jnp.minimum(local + pick, local + jnp.maximum(deg - 1, 0))
    ids = jnp.concatenate([idx_lo_ref[...], idx_hi_ref[...]])
    oh = (offs == pick).astype(jnp.float32)
    nxt = jnp.sum(oh * ids.astype(jnp.float32)).astype(jnp.int32)
    dead = (deg <= 0) | (total <= _EPS)
    out_ref[0] = jnp.where(dead, -1, nxt)


def _walk_step_window_kernel(
    starts_ref,  # scalar-prefetch (W,)
    degs_ref,  # scalar-prefetch (W,)
    rand_ref,  # (1,) this walker's uniform
    bias_ref,  # (1, 2*max_seg) this walker's window-aligned bias row
    idx_lo_ref,  # (max_seg,) neighbor-id block containing `start`
    idx_hi_ref,  # (max_seg,) following block
    out_ref,  # (1,) next vertex
    *,
    max_seg: int,
):
    """Window-bias variant of the walk step (transition programs, DESIGN.md
    §10): the per-edge bias is a *computed operand* — evaluated by the
    engine's dynamic edge-bias hook on this walker's gathered edge window —
    instead of a slice of a static flat CSR array.  Neighbor ids still
    arrive by segment DMA; the ITS pick is identical to the flat kernel."""
    w = pl.program_id(0)
    start = starts_ref[w]
    deg = degs_ref[w]
    local = start % max_seg  # offset inside the 2-block window
    offs = jax.lax.broadcasted_iota(jnp.int32, (2 * max_seg,), 0)
    mask = (offs >= local) & (offs < local + deg)
    wts = jnp.where(mask, bias_ref[0, :], 0.0)
    cum = jnp.cumsum(wts)
    total = cum[-1]
    target = rand_ref[0] * total
    pick = jnp.sum(((cum <= target) & mask).astype(jnp.int32))
    pick = jnp.minimum(local + pick, local + jnp.maximum(deg - 1, 0))
    ids = jnp.concatenate([idx_lo_ref[...], idx_hi_ref[...]])
    oh = (offs == pick).astype(jnp.float32)
    nxt = jnp.sum(oh * ids.astype(jnp.float32)).astype(jnp.int32)
    dead = (deg <= 0) | (total <= _EPS)
    out_ref[0] = jnp.where(dead, -1, nxt)


def _reject_step_kernel(
    starts_ref,  # scalar-prefetch (W,)
    degs_ref,  # scalar-prefetch (W,)
    rej_ref,  # (1, 2*iters) this walker's [slot, accept] uniform rounds
    rowmax_ref,  # (1,) this walker's rejection envelope (row max bias)
    idx_lo_ref,  # (max_seg,) neighbor-id blocks
    idx_hi_ref,
    w_lo_ref,  # (max_seg,) bias blocks
    w_hi_ref,
    out_ref,  # (1,) next vertex
    *,
    max_seg: int,
    iters: int,
):
    """Counted-RNG rejection walk step (adaptive selection runtime,
    DESIGN.md §13): round ``t`` proposes ``slot = floor(r_slot * deg)`` and
    accepts iff ``r_acc * row_max < bias[slot]`` — first acceptance wins,
    an exhausted budget falls back to the last candidate carrying mass.
    Static unroll; exactly ``core.select.rejection_draw_flat`` with
    ``cap = max_seg`` (bit-identical across backends)."""
    w = pl.program_id(0)
    start = starts_ref[w]
    deg = degs_ref[w]
    deg_eff = jnp.minimum(deg, max_seg)
    degf = deg_eff.astype(jnp.float32)
    local = start % max_seg
    offs = jax.lax.broadcasted_iota(jnp.int32, (2 * max_seg,), 0)
    wts = jnp.concatenate([w_lo_ref[...], w_hi_ref[...]])
    rm = rowmax_ref[0]
    chosen = jnp.full((), -1, jnp.int32)
    done = jnp.full((), False)
    last = jnp.full((), 0, jnp.int32)
    last_b = jnp.full((), 0.0, jnp.float32)
    for t in range(iters):
        slot = jnp.minimum(
            (rej_ref[0, 2 * t] * degf).astype(jnp.int32), jnp.maximum(deg_eff - 1, 0)
        )
        bval = jnp.sum((offs == local + slot).astype(jnp.float32) * wts)
        acc = rej_ref[0, 2 * t + 1] * rm < bval
        chosen = jnp.where(~done & acc, slot, chosen)
        last, last_b = slot, bval
        done = done | acc
    chosen = jnp.where(done, chosen, jnp.where(last_b > 0, last, -1))
    ids = jnp.concatenate([idx_lo_ref[...], idx_hi_ref[...]])
    oh = (offs == local + jnp.maximum(chosen, 0)).astype(jnp.float32)
    nxt = jnp.sum(oh * ids.astype(jnp.float32)).astype(jnp.int32)
    dead = (deg <= 0) | (rm <= 0) | (chosen < 0)
    out_ref[0] = jnp.where(dead, -1, nxt)


def pad_csr_for_kernel(indices: jax.Array, weights: jax.Array, max_seg: int):
    """Pad flat CSR edge arrays to a block multiple plus one spill block."""
    e = indices.shape[0]
    target = ((e + max_seg - 1) // max_seg + 1) * max_seg
    pad = target - e
    return (
        jnp.pad(indices, (0, pad), constant_values=0),
        jnp.pad(weights, (0, pad), constant_values=0.0),
    )


@functools.partial(jax.jit, static_argnames=("max_seg", "interpret"))
def walk_step_pallas(
    starts: jax.Array,
    degs: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    rand: jax.Array,
    *,
    max_seg: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """One weighted walk step for W walkers.

    starts/degs: (W,) int32 row offsets/degrees (deg <= max_seg — the
    engine's degree-bucketed scheduler guarantees this per cohort,
    DESIGN.md §6); indices/weights: flat CSR arrays padded via
    :func:`pad_csr_for_kernel`; rand: (W,) uniforms.  Returns next
    vertices (W,) int32 (-1 dead end).
    """
    w = starts.shape[0]
    e = indices.shape[0]
    assert e % max_seg == 0, "pad CSR edge arrays with pad_csr_for_kernel"

    def lo_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg,)

    def hi_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg + 1,)

    def per_walker(i, starts_ref, degs_ref):
        return (i,)

    kernel = functools.partial(_walk_step_kernel, max_seg=max_seg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1,), per_walker),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
        ],
        out_specs=pl.BlockSpec((1,), per_walker),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(starts, degs, rand, indices, indices, weights, weights)


@functools.partial(jax.jit, static_argnames=("max_seg", "interpret"))
def walk_step_window_pallas(
    starts: jax.Array,
    degs: jax.Array,
    indices: jax.Array,
    bias_win: jax.Array,
    rand: jax.Array,
    *,
    max_seg: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """One dynamic-bias walk step for W walkers (transition programs).

    Like :func:`walk_step_pallas` but the per-edge bias is ``bias_win``:
    ``(W, 2*max_seg)`` float32 rows, one per walker, aligned with the
    kernel's 2-block edge window (the walker's neighbors sit at offsets
    ``[start % max_seg, start % max_seg + deg)``).  ``indices`` is the
    padded flat CSR id array (:func:`pad_csr_for_kernel`).
    """
    w = starts.shape[0]
    e = indices.shape[0]
    assert e % max_seg == 0, "pad CSR edge arrays with pad_csr_for_kernel"
    assert bias_win.shape == (w, 2 * max_seg), bias_win.shape

    def lo_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg,)

    def hi_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg + 1,)

    def per_walker(i, starts_ref, degs_ref):
        return (i,)

    def bias_row(i, starts_ref, degs_ref):
        return (i, 0)

    kernel = functools.partial(_walk_step_window_kernel, max_seg=max_seg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1,), per_walker),
            pl.BlockSpec((1, 2 * max_seg), bias_row),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
        ],
        out_specs=pl.BlockSpec((1,), per_walker),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(starts, degs, rand, bias_win, indices, indices)


@functools.partial(jax.jit, static_argnames=("max_seg", "interpret"))
def reject_step_pallas(
    starts: jax.Array,
    degs: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    row_max: jax.Array,
    rej: jax.Array,
    *,
    max_seg: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """One rejection-sampled walk step for W walkers (near-uniform biases).

    starts/degs: (W,) int32 row offsets/degrees; indices/weights: flat CSR
    arrays padded via :func:`pad_csr_for_kernel`; row_max: (W,) float32
    per-walker envelopes (each walker's row max bias, gathered by the
    engine); rej: (W, iters, 2) counted budget from
    ``core.select.rejection_randoms``.  Returns next vertices (W,) int32
    (-1 dead end).
    """
    w = starts.shape[0]
    e = indices.shape[0]
    assert e % max_seg == 0, "pad CSR edge arrays with pad_csr_for_kernel"
    assert rej.ndim == 3 and rej.shape[0] == w and rej.shape[2] == 2, rej.shape
    iters = rej.shape[1]
    rej2 = rej.reshape(w, 2 * iters)

    def lo_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg,)

    def hi_map(i, starts_ref, degs_ref):
        return (starts_ref[i] // max_seg + 1,)

    def per_walker(i, starts_ref, degs_ref):
        return (i,)

    def rej_row(i, starts_ref, degs_ref):
        return (i, 0)

    kernel = functools.partial(_reject_step_kernel, max_seg=max_seg, iters=iters)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, 2 * iters), rej_row),
            pl.BlockSpec((1,), per_walker),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
            pl.BlockSpec((max_seg,), lo_map),
            pl.BlockSpec((max_seg,), hi_map),
        ],
        out_specs=pl.BlockSpec((1,), per_walker),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(starts, degs, rej2, row_max, indices, indices, weights, weights)
