"""Production mesh construction (DESIGN.md §5).

A FUNCTION (not module-level state) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
