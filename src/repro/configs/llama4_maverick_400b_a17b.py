"""llama4-maverick-400b-a17b [moe] — MoE top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 128e top-1.  Maverick interleaves MoE with dense layers (1:1) and adds
a shared-expert FFN (d_ff) in parallel with the routed top-1 expert —
that is what lands total params at ~400B with 17B active.  Adafactor.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=("global_dense", "global"),
    num_experts=128,
    num_experts_per_tok=1,
    moe_dense_ff=8192,  # shared expert
    capacity_factor=1.25,
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    optimizer="adafactor",
    microbatches=4,
    reduce_dtype="bf16",
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("global_dense", "global"),
    num_experts=4,
    num_experts_per_tok=1,
    moe_dense_ff=128,
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
