"""Paper Fig. 17: multi-device scaling (instance-parallel, zero-comm).

Runs subprocesses with ``--xla_force_host_platform_device_count=N`` so the
parent process keeps its single-device view (per the dry-run isolation
rule).  Wall-clock on shared host cores is not a throughput claim — the
reported figure is the *work distribution* (instances per device) plus the
collective-free execution, matching the paper's scaling argument; the
multipod dry-run provides the compile-level proof.
"""
from __future__ import annotations

import json
import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk

n = %d
g = powerlaw_graph(20000, exponent=2.1, seed=7, weighted=True)
mesh = jax.make_mesh((n,), ("data",))
key = jax.random.PRNGKey(0)
seeds = jax.random.randint(key, (4096,), 0, g.num_vertices)
md = min(g.max_degree(), 512)
run = lambda: instance_parallel_walk(mesh, g, seeds, key, depth=32,
                                     spec=alg.biased_random_walk(), max_degree=md)
jax.block_until_ready(run().walks)
t0 = time.perf_counter()
res = run()
jax.block_until_ready(res.walks)
secs = time.perf_counter() - t0
print(json.dumps({"devices": n, "secs": secs, "edges": int(res.sampled_edges)}))
"""


def run() -> list[str]:
    rows = []
    for n in (1, 2, 4):
        out = subprocess.run(
            [sys.executable, "-c", _CHILD % (n, n)],
            capture_output=True, text=True, timeout=900,
        )
        line = out.stdout.strip().splitlines()[-1]
        d = json.loads(line)
        rows.append(row(
            f"fig17/devices={n}", d["secs"] * 1e6,
            f"SEPS={d['edges']/d['secs']:.3e};inst_per_dev={4096//n}",
        ))
    return rows
