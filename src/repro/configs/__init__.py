"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

One module per assigned architecture; each exposes ``CONFIG`` (exact config
from the assignment) and ``SMOKE`` (reduced same-family config for CPU
tests).  ``--arch <id>`` resolves through :func:`get_config`.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "xlstm_350m",
    "gemma3_1b",
    "internlm2_1_8b",
    "gemma_7b",
    "starcoder2_3b",
    "recurrentgemma_9b",
    "arctic_480b",
    "llama4_maverick_400b_a17b",
    "musicgen_medium",
    "internvl2_26b",
)

# accepted aliases (dashes as assigned)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({"llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b"})


def _resolve(arch: str) -> str:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return arch


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_resolve(arch)}").CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_resolve(arch)}").SMOKE
