"""Owner-routed sharded sampling (repro.shard, DESIGN.md §12/§14).

Two layers:

- In-process tests of the exchange machinery (queue push/pop, per-
  destination routing with overflow deferral, per-device footprint,
  sustained single-hot-owner pressure) and of the hub-replicated hybrid
  layout's host staging (budgeted hub selection, alignment-preserving
  hub edge placement, three-way ``localize_hybrid``, H=0 ≡ legacy) —
  pure fixed-shape array programs, no mesh required.
- Subprocess tests on a forced 8-host-device mesh (same harness as
  ``test_multidevice.py``): the bit-identical parity contract of
  ``sharded_random_walk`` vs single-device ``random_walk`` for EVERY
  non-opaque program family — flat, window, ``needs_deg_u`` window, MH
  acceptance, teleport — on both backends, with hubs on and off;
  overflow round-trips, the adversarial all-walkers-into-one-owner star,
  the exchange-reduction stats contract, the ``placement="sharded"``
  service target, and the instance-parallel key-disjointness fix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import MULTIDEVICE_HEADER as HEADER, run_multidevice_child as run_child
from repro.shard import exchange as ex


# ---------------------------------------------------------------------------
# Exchange machinery (in-process, no mesh)
# ---------------------------------------------------------------------------


class TestExchange:
    def test_queue_push_pop_roundtrip_with_payload(self):
        q = ex.make_queue(8, (0, 0, 2))
        ent = (
            jnp.array([5, -1, 7, 9], jnp.int32),
            jnp.array([0, -1, 1, 2], jnp.int32),
            jnp.array([[10, 11], [0, 0], [12, 13], [14, 15]], jnp.int32),
        )
        valid = jnp.array([True, False, True, True])
        q = ex.queue_push(q, ent, valid)
        assert int(q.count) == 3 and int(q.dropped) == 0
        # valid entries keep batch order, front-packed
        np.testing.assert_array_equal(np.asarray(q.fields[0][:3]), [5, 7, 9])
        np.testing.assert_array_equal(np.asarray(q.fields[2][0]), [10, 11])

        out, taken, q = ex.queue_pop(q, 2)
        assert int(taken) == 2
        np.testing.assert_array_equal(np.asarray(out[0]), [5, 7])
        np.testing.assert_array_equal(np.asarray(out[2]), [[10, 11], [12, 13]])
        # survivor re-compacted to the front
        assert int(q.count) == 1 and int(q.fields[0][0]) == 9
        assert int(q.fields[1][1]) == -1  # vacated slot cleared

    def test_queue_pop_limit_caps_take(self):
        q = ex.make_queue(4, (0, 0))
        q = ex.queue_push(
            q,
            (jnp.arange(4, dtype=jnp.int32), jnp.arange(4, dtype=jnp.int32)),
            jnp.ones(4, bool),
        )
        out, taken, q = ex.queue_pop(q, 4, limit=jnp.int32(1))
        assert int(taken) == 1 and int(q.count) == 3
        np.testing.assert_array_equal(np.asarray(out[0]), [0, -1, -1, -1])

    def test_queue_push_overflow_counted(self):
        q = ex.make_queue(2, (0, 0))
        ent = (jnp.arange(4, dtype=jnp.int32), jnp.arange(4, dtype=jnp.int32))
        q = ex.queue_push(q, ent, jnp.ones(4, bool))
        assert int(q.count) == 2 and int(q.dropped) == 2

    def test_route_by_owner_buckets_and_defers(self):
        # 6 valid entries: dests [0, 1, 1, 1, 0, 1]; slots=2 per destination
        vert = jnp.array([0, 10, 11, 12, 1, 13, -1, -1], jnp.int32)
        inst = jnp.array([0, 1, 2, 3, 4, 5, -1, -1], jnp.int32)
        dest = jnp.array([0, 1, 1, 1, 0, 1, 0, 0], jnp.int32)
        valid = inst >= 0
        send, sent, leftover, left = ex.route_by_owner(
            (vert, inst), dest, valid, num_dest=2, slots=2
        )
        np.testing.assert_array_equal(np.asarray(sent), [2, 2])
        # batch order within destination: older entries win the slots
        np.testing.assert_array_equal(np.asarray(send[0][0]), [0, 1])
        np.testing.assert_array_equal(np.asarray(send[0][1]), [10, 11])
        # the two overflowing dest-1 entries defer, front-packed, in order
        assert int(left) == 2
        np.testing.assert_array_equal(np.asarray(leftover[0][:2]), [12, 13])
        assert int(leftover[1][2]) == -1

    def test_route_then_push_conserves_entries(self):
        """Capacity round-trip: routed + deferred + queued == offered."""
        rng = np.random.default_rng(0)
        n, d, slots = 64, 4, 5
        vert = jnp.asarray(rng.integers(0, 40, n).astype(np.int32))
        inst = jnp.asarray(np.arange(n, dtype=np.int32))
        dest = (vert // 10).astype(jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        send, sent, leftover, left = ex.route_by_owner(
            (vert, inst), dest, valid, num_dest=d, slots=slots
        )
        assert int(sent.sum() + left) == int(valid.sum())
        assert int(sent.max()) <= slots
        # every sent + deferred instance id appears exactly once
        ids = np.concatenate(
            [np.asarray(send[1]).ravel(), np.asarray(leftover[1])]
        )
        ids = ids[ids >= 0]
        expect = np.asarray(inst)[np.asarray(valid)]
        np.testing.assert_array_equal(np.sort(ids), np.sort(expect))


# ---------------------------------------------------------------------------
# Per-device footprint (host-side property of the shard layout)
# ---------------------------------------------------------------------------


def test_per_device_csr_footprint_scales_inverse_with_devices():
    """Each shard ships O(V/D + E_D) arrays — never the O(V) indptr of the
    replicated-psum layout — and per-device edge storage shrinks with D."""
    from repro.graph import powerlaw_graph
    from repro.graph.partition import PartitionMap, partition_by_vertex_range

    g = powerlaw_graph(4096, seed=7, weighted=True)
    e_total = g.num_edges
    prev_pad_e = None
    for ndev in (2, 4, 8):
        pm = PartitionMap.create(g.num_vertices, ndev)
        parts = partition_by_vertex_range(g, ndev)
        align = 512
        pad_e = max((p.edge_lo % align) + p.num_edges for p in parts)
        dev = parts[0].to_local_device_csr(
            pad_vertices=pm.range_size, pad_edges=pad_e, edge_align=align
        )
        # indptr rows ∝ V/D (+2: phantom sink + fence), not V+1
        assert dev.graph.indptr.shape[0] == pm.range_size + 2
        # per-device edge arrays well under the full graph, shrinking with D
        assert pad_e <= 3 * e_total // ndev + align
        if prev_pad_e is not None:
            assert pad_e < prev_pad_e
        prev_pad_e = pad_e


def test_edge_alignment_preserves_global_block_offsets():
    from repro.graph import powerlaw_graph
    from repro.graph.partition import partition_by_vertex_range

    g = powerlaw_graph(1024, seed=3, weighted=True)
    parts = partition_by_vertex_range(g, 4)
    indptr = np.asarray(g.indptr)
    for p in parts:
        dev = p.to_local_device_csr(edge_align=512)
        local = np.asarray(dev.graph.indptr)
        for v in range(p.vertex_lo, min(p.vertex_hi, p.vertex_lo + 50)):
            assert local[v - p.vertex_lo] % 512 == indptr[v] % 512


# ---------------------------------------------------------------------------
# Hub-replicated hybrid layout (host-side, DESIGN.md §14)
# ---------------------------------------------------------------------------


class TestHubLayout:
    def _graph(self, v=1024, seed=3):
        from repro.graph import powerlaw_graph

        return powerlaw_graph(v, seed=seed, weighted=True)

    def test_select_hubs_budget_and_order(self):
        from repro.graph.partition import select_hubs

        g = self._graph()
        indptr = np.asarray(g.indptr)
        deg = np.diff(indptr)
        hubs = select_hubs(indptr, hub_bytes=200_000, seg_big=512)
        assert hubs.size > 0
        # sorted ascending (the traced lookup binary-searches this array)
        np.testing.assert_array_equal(hubs, np.sort(hubs))
        # greedy by degree: every hub at least as hot as every non-hub
        non = np.setdiff1d(np.arange(g.num_vertices), hubs)
        assert deg[hubs].min() >= deg[non].max() - 0  # ties broken stably
        # budget honored: replicated footprint within hub_bytes
        assert ((deg[hubs].astype(np.int64) + 512) * 28).sum() <= 200_000
        # degenerate budgets
        assert select_hubs(indptr, 0, 512).size == 0
        assert select_hubs(indptr, -5, 512).size == 0

    def test_hub_edge_layout_preserves_global_alignment(self):
        from repro.graph.partition import hub_edge_layout, select_hubs

        g = self._graph()
        indptr = np.asarray(g.indptr)
        hubs = select_hubs(indptr, 300_000, 512)
        starts, end = hub_edge_layout(indptr, hubs, hub_region_lo=4096, seg_big=512)
        deg = np.diff(indptr)
        cur = 4096
        for s, h in enumerate(hubs):
            # the §12 invariant: a replicated row keeps its global block
            # offset, so its pick cumsum reproduces the full-graph bits
            assert starts[s] % 512 == indptr[h] % 512
            assert cur <= starts[s] < cur + 512  # at most one lead gap
            cur = starts[s] + deg[h]
        assert end == cur

    def test_hybrid_host_csr_no_hubs_is_legacy_layout(self):
        from repro.graph.partition import hybrid_host_csr, partition_by_vertex_range

        g = self._graph()
        parts = partition_by_vertex_range(g, 4)
        ip_f = np.asarray(g.indptr)
        ind_f = np.asarray(g.indices)
        w_f = np.asarray(g.weights)
        p = parts[1]
        pad_e = (p.edge_lo % 512) + p.num_edges
        dev = p.to_local_device_csr(pad_vertices=300, pad_edges=pad_e, edge_align=512)
        ip, il, ig, w = hybrid_host_csr(
            p, 300, pad_e, 512, np.empty(0, np.int64), np.empty(0, np.int64),
            ip_f, ind_f, w_f,
        )
        np.testing.assert_array_equal(ip, np.asarray(dev.graph.indptr))
        np.testing.assert_array_equal(il, np.asarray(dev.graph.indices))
        np.testing.assert_array_equal(ig, np.asarray(dev.indices_global))
        np.testing.assert_array_equal(w, np.asarray(dev.graph.weights))

    def test_hybrid_host_csr_hub_rows_replicate_full_rows(self):
        from repro.graph.partition import (
            hub_edge_layout,
            hybrid_host_csr,
            partition_by_vertex_range,
            select_hubs,
        )

        g = self._graph()
        parts = partition_by_vertex_range(g, 4)
        ip_f = np.asarray(g.indptr)
        ind_f = np.asarray(g.indices)
        w_f = np.asarray(g.weights)
        hubs = select_hubs(ip_f, 300_000, 512)
        H = int(hubs.size)
        assert H >= 2
        pad_e_local = max((p.edge_lo % 512) + p.num_edges for p in parts)
        hub_lo = -(-pad_e_local // 512) * 512
        starts, end = hub_edge_layout(ip_f, hubs, hub_lo, 512)
        pv = parts[0].num_vertices
        for p in parts:
            ip, il, ig, w = hybrid_host_csr(
                p, pv, max(pad_e_local, end), 512, hubs, starts, ip_f, ind_f, w_f
            )
            assert ip.shape[0] == pv + 2 * H + 2
            phantom = pv + 2 * H
            assert ip[phantom + 1] == ip[phantom]  # degree-0 sink
            for s, h in enumerate(hubs):
                row = pv + 1 + 2 * s
                st, en = int(ip[row]), int(ip[row + 1])
                g0, g1 = int(ip_f[h]), int(ip_f[h + 1])
                assert en - st == g1 - g0  # full row, every device
                np.testing.assert_array_equal(ig[st:en], ind_f[g0:g1])
                np.testing.assert_array_equal(w[st:en], w_f[g0:g1])

    def test_localize_hybrid_three_way_mapping(self):
        from repro.graph.partition import localize_hybrid

        hubs = jnp.asarray(np.array([7, 300, 901], np.int32))
        x = jnp.asarray(np.array([100, 139, 7, 300, 901, 50, 990, -1], np.int32))
        # resident range [100, 140), 3 hubs, phantom = 40 + 6 = 46
        loc = np.asarray(localize_hybrid(x, jnp.int32(100), 40, hubs, 3))
        np.testing.assert_array_equal(loc, [0, 39, 41, 43, 45, 46, 46, 46])
        # no hubs: legacy two-way mapping
        loc0 = np.asarray(localize_hybrid(x, jnp.int32(100), 40, hubs, 0))
        np.testing.assert_array_equal(loc0, [0, 39, 40, 40, 40, 40, 40, 40])


# ---------------------------------------------------------------------------
# Adversarial exchange pressure (in-process): one hot owner, tiny slots
# ---------------------------------------------------------------------------


class TestExchangePressure:
    def test_single_hot_owner_sustained_pressure(self):
        """All 48 walkers target owner 1 with slots=4: the deferral pipeline
        must drain them over ceil(48/4) rounds with NOTHING dropped and
        seniority preserved — round k ships exactly the k-th oldest slice."""
        n, slots, hot = 48, 4, 1
        vert = jnp.asarray(np.full(n, 17, np.int32))
        inst = jnp.asarray(np.arange(n, dtype=np.int32))
        dest = jnp.asarray(np.full(n, hot, np.int32))
        fields = (vert, inst)
        valid = jnp.ones(n, bool)
        shipped = []
        for _ in range(n // slots):
            send, sent, leftover, left = ex.route_by_owner(
                fields, dest, valid, num_dest=4, slots=slots
            )
            assert int(sent[hot]) == slots
            assert int(sent.sum()) == slots  # only the hot owner ships
            shipped.extend(np.asarray(send[1][hot]).tolist())
            fields = leftover
            valid = jnp.arange(n) < left
            dest = jnp.asarray(np.full(n, hot, np.int32))
        assert int(left) == 0
        # FIFO seniority: generation order survives arbitrary re-offering
        np.testing.assert_array_equal(shipped, np.arange(n))

    def test_pop_throttling_under_deferred_backlog(self):
        """The drain's invariant: pop at most (cap - deferred) so one batch
        always fits; with a full backlog the pop must yield nothing."""
        cap = 8
        q = ex.make_queue(cap, (0, 0))
        q = ex.queue_push(
            q,
            (jnp.arange(cap, dtype=jnp.int32), jnp.arange(cap, dtype=jnp.int32)),
            jnp.ones(cap, bool),
        )
        for backlog in (0, 3, cap):
            out, taken, _ = ex.queue_pop(q, cap, limit=cap - jnp.int32(backlog))
            assert int(taken) == cap - backlog
            got = np.asarray(out[1])
            np.testing.assert_array_equal(got[: cap - backlog],
                                          np.arange(cap - backlog))
            assert (got[cap - backlog :] == -1).all()

    def test_defer_then_route_conserves_and_orders(self):
        """queue_push into a deferred buffer then route: entries leave in
        push order, overflow re-queues front-packed, zero losses."""
        cap, slots = 16, 3
        defer = ex.make_queue(cap, (0, 0))
        # three generations of pushes (4 + 4 + 4), all for owner 0
        for gen in range(3):
            batch = (
                jnp.asarray(np.full(4, gen, np.int32)),
                jnp.asarray(np.arange(gen * 4, gen * 4 + 4, dtype=np.int32)),
            )
            defer = ex.queue_push(defer, batch, jnp.ones(4, bool))
        assert int(defer.count) == 12 and int(defer.dropped) == 0
        dmask = jnp.arange(cap) < defer.count
        dest = jnp.zeros(cap, jnp.int32)
        send, sent, leftover, left = ex.route_by_owner(
            defer.fields, dest, dmask, num_dest=2, slots=slots
        )
        np.testing.assert_array_equal(np.asarray(send[1][0]), [0, 1, 2])
        assert int(left) == 9
        np.testing.assert_array_equal(np.asarray(leftover[1][:9]), np.arange(3, 12))


# ---------------------------------------------------------------------------
# Mesh execution (subprocess, forced 8 host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_walk_bit_identical_reference_backend():
    """Flat AND window programs, 4- and 8-way meshes, reference backend:
    sharded == single-device bit for bit, including with tiny exchange
    buffers (overflow deferred across rounds, never dropped)."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
g = powerlaw_graph(1500, exponent=1.9, seed=5, weighted=True)
md = g.max_degree()
seeds = jax.random.randint(jax.random.PRNGKey(0), (96,), 0, g.num_vertices)
key = jax.random.PRNGKey(11)
out = {}
for D in (4, 8):
    mesh = jax.make_mesh((D,), ("data",))
    for spec, kw in [
        (alg.deepwalk(), {}),
        (alg.weighted_random_walk(), {}),
        (alg.biased_random_walk(), {}),          # neighbor-degree flat bias
        (alg.node2vec(), {}),                    # prev-carried window bias
        (alg.random_walk_with_restart(0.25), {}),  # teleport-home epilogue
        (alg.deepwalk(), dict(exchange_slots=3)),  # forced overflow deferral
        (alg.node2vec(), dict(exchange_slots=4)),
    ]:
        ref = random_walk(g, seeds, key, depth=10, spec=spec,
                          max_degree=md, backend="reference")
        res = sharded_random_walk(mesh, g, seeds, key, depth=10, spec=spec,
                                  max_degree=md, backend="reference", **kw)
        tag = f"{D}/{spec.name}/{'slots' if kw else 'full'}"
        out[tag] = bool(jnp.array_equal(ref.walks, res.walks)) and bool(
            jnp.array_equal(ref.lengths, res.lengths))
print(json.dumps(out))
""")
    assert all(d.values()), {k: v for k, v in d.items() if not v}


@pytest.mark.slow
def test_sharded_walk_bit_identical_pallas_backend():
    """Interpret-mode Pallas under shard_map: same bits as the single-device
    pallas path for a flat and a window program."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
g = powerlaw_graph(300, seed=3, weighted=True)
md = g.max_degree()
seeds = jax.random.randint(jax.random.PRNGKey(0), (24,), 0, g.num_vertices)
key = jax.random.PRNGKey(7)
mesh = jax.make_mesh((4,), ("data",))
out = {}
for spec in (alg.deepwalk(), alg.node2vec()):
    ref = random_walk(g, seeds, key, depth=4, spec=spec,
                      max_degree=md, backend="pallas")
    res = sharded_random_walk(mesh, g, seeds, key, depth=4, spec=spec,
                              max_degree=md, backend="pallas")
    out[spec.name] = bool(jnp.array_equal(ref.walks, res.walks))
print(json.dumps(out))
""", timeout=600)
    assert all(d.values()), d


@pytest.mark.slow
def test_sharded_walk_hub_degrees_hit_every_cohort():
    """Degrees spanning small bucket, medium bucket, and the chunked
    huge-degree tail (> 512) stay bit-identical across the exchange."""
    d = run_child(HEADER + """
from repro.graph import csr_from_edges
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.shard import sharded_random_walk
rng = np.random.default_rng(0)
V = 2000
src = np.concatenate([np.zeros(900, int), np.full(300, 1000), rng.integers(0, V, 4000)])
dst = np.concatenate([rng.integers(1, V, 900), rng.integers(0, V, 300), rng.integers(0, V, 4000)])
w = rng.random(src.shape[0]).astype(np.float32) + 0.1
g = csr_from_edges(V, src, dst, weights=w, symmetrize=True)
md = g.max_degree()
assert md > 512  # the chunked tail must actually engage
seeds = jnp.asarray(np.concatenate([[0, 1000], rng.integers(0, V, 62)]).astype(np.int32))
key = jax.random.PRNGKey(13)
mesh = jax.make_mesh((8,), ("data",))
out = {"maxdeg": int(md)}
for spec in (alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()):
    ref = random_walk(g, seeds, key, depth=8, spec=spec, max_degree=md, backend="reference")
    res = sharded_random_walk(mesh, g, seeds, key, depth=8, spec=spec, max_degree=md, backend="reference")
    out[spec.name] = bool(jnp.array_equal(ref.walks, res.walks))
print(json.dumps(out))
""")
    assert d["maxdeg"] > 512
    assert all(v for k, v in d.items() if k != "maxdeg"), d


@pytest.mark.slow
def test_sharded_service_cohorts():
    """placement="sharded": heterogeneous request cohorts drain through the
    mesh, return exact per-request geometry, walk real edges, and are
    deterministic across identically-constructed services."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.serve import SamplingService
g = powerlaw_graph(1000, seed=0, weighted=True)
mesh = jax.make_mesh((8,), ("data",))

def serve():
    svc = SamplingService(g, mesh=mesh, placement="sharded",
                          backend="reference", key=jax.random.PRNGKey(9))
    rng = np.random.default_rng(1)
    tickets = {}
    for i in range(8):
        spec = [alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()][i % 3]
        n, dep = int(rng.integers(8, 49)), int(rng.choice([4, 6, 10]))
        rid = svc.submit(rng.integers(0, 1000, n), depth=dep, spec=spec)
        tickets[rid] = (n, dep)
    return svc, tickets, svc.drain()

svc, tickets, res = serve()
_, _, res2 = serve()
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
geom_ok, edges_ok, det_ok = True, True, True
for rid, (n, dep) in tickets.items():
    r = res[rid]
    geom_ok &= r.walks.shape == (n, dep + 1) and bool((r.lengths >= 1).all())
    det_ok &= bool(np.array_equal(r.walks, res2[rid].walks))
    for row in r.walks:
        for a, b in zip(row[:-1], row[1:]):
            if a < 0 or b < 0: break
            edges_ok &= b in ind[ip[a]:ip[a+1]]
print(json.dumps({"geom": geom_ok, "edges": bool(edges_ok), "det": det_ok,
                  "launches": svc.stats.sharded_launches}))
""")
    assert d["geom"] and d["edges"] and d["det"] and d["launches"] >= 1


@pytest.mark.slow
def test_instance_parallel_streams_disjoint_across_mesh_sizes():
    """Folding the axis size means device d of a 2-way and a 4-way mesh draw
    different streams — before the fix, the first instance group's walks
    were identical across mesh widths (same ``fold_in(key, d)``)."""
    d = run_child(HEADER + """
from repro.graph import powerlaw_graph
from repro.core import algorithms as alg
from repro.core.distributed import instance_parallel_walk
g = powerlaw_graph(512, seed=1, weighted=True)
seeds = jax.random.randint(jax.random.PRNGKey(0), (64,), 0, 512)
runs = {}
for D in (2, 4):
    mesh = jax.make_mesh((D,), ("data",))
    res = instance_parallel_walk(mesh, g, seeds, jax.random.PRNGKey(1), depth=16,
                                 spec=alg.deepwalk(), max_degree=g.max_degree())
    runs[D] = np.asarray(res.walks)
# device 0 of the 4-way mesh owns instances [0:16); under the old keying it
# replayed device 0 of the 2-way mesh verbatim
head_differs = not np.array_equal(runs[2][:16], runs[4][:16])
ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
bad = 0
for row in runs[4]:
    for a, b in zip(row[:-1], row[1:]):
        if a < 0 or b < 0: break
        if b not in ind[ip[a]:ip[a+1]]: bad += 1
print(json.dumps({"head_differs": bool(head_differs), "bad": bad}))
""")
    assert d["head_differs"] and d["bad"] == 0


@pytest.mark.slow
def test_sharded_walk_mh_and_degu_window_parity_matrix():
    """The programs this PR moved off the replicated-psum fallback — MH
    acceptance and ``needs_deg_u`` window biases — owner-routed at D=8 on
    BOTH backends, bit-identical to single-device, with hub replication
    measurably cutting exchange traffic (stats contract)."""
    d = run_child(HEADER + """
from repro.core import algorithms as alg
from repro.core.api import SamplingSpec
from repro.core.engine import random_walk
from repro.core.transition import TransitionProgram, WindowBias
from repro.graph import powerlaw_graph
from repro.shard import sharded_random_walk

def degu_spec():
    wb = WindowBias(lambda ctx: ctx.weight / jnp.maximum(ctx.deg_u, 1),
                    needs_deg_u=True)
    return SamplingSpec(name="degu_window", transition=TransitionProgram(bias=wb))

g = powerlaw_graph(1500, exponent=1.9, seed=5, weighted=True)
md = g.max_degree()
seeds = jax.random.randint(jax.random.PRNGKey(0), (96,), 0, g.num_vertices)
key = jax.random.PRNGKey(11)
mesh = jax.make_mesh((8,), ("data",))
out = {}
stats = {}
for spec in (alg.metropolis_hastings_walk(), degu_spec()):
    ref = random_walk(g, seeds, key, depth=10, spec=spec,
                      max_degree=md, backend="reference")
    for hb, tag in ((None, "hubs"), (0, "nohubs")):
        res = sharded_random_walk(mesh, g, seeds, key, depth=10, spec=spec,
                                  max_degree=md, backend="reference",
                                  hub_bytes=hb)
        out[f"ref/{spec.name}/{tag}"] = bool(
            jnp.array_equal(ref.walks, res.walks)) and bool(
            jnp.array_equal(ref.lengths, res.lengths))
        stats[f"{spec.name}/{tag}"] = res.stats

# pallas (interpret mode is slow: small graph, shallow walks)
gs = powerlaw_graph(300, seed=3, weighted=True)
mds = gs.max_degree()
seeds_s = jax.random.randint(jax.random.PRNGKey(0), (24,), 0, gs.num_vertices)
for spec in (alg.metropolis_hastings_walk(), degu_spec()):
    ref = random_walk(gs, seeds_s, key, depth=3, spec=spec,
                      max_degree=mds, backend="pallas")
    res = sharded_random_walk(mesh, gs, seeds_s, key, depth=3, spec=spec,
                              max_degree=mds, backend="pallas")
    out[f"pallas/{spec.name}"] = bool(jnp.array_equal(ref.walks, res.walks))

hub_ok = all(
    s["num_hubs"] > 0 and s["hub_hops"] > 0
    and s["exchanged_entries"] <= stats[k.replace("/hubs", "/nohubs")]["exchanged_entries"]
    for k, s in stats.items() if k.endswith("/hubs"))
print(json.dumps(dict(out, hub_ok=hub_ok,
                      sample=stats["mhrw/hubs"])))
""", timeout=600)
    sample = d.pop("sample")
    hub_ok = d.pop("hub_ok")
    assert all(d.values()), {k: v for k, v in d.items() if not v}
    assert hub_ok, sample
    assert sample["exchange_bytes"] == sample["exchanged_entries"] * sample["entry_bytes"]


@pytest.mark.slow
def test_sharded_walk_adversarial_hot_owner_star():
    """Every walker funnels into ONE owner (star graph, 8-way mesh): with
    ``hub_bytes=0`` and a 2-slot exchange buffer, the deferral pipeline must
    still deliver bit-identical walks (no walker dropped under sustained
    pressure); replicating the hub then converts the spoke->hub half of the
    traffic into local hops."""
    d = run_child(HEADER + """
from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.graph import csr_from_edges
from repro.shard import sharded_random_walk
V = 257
spokes = np.arange(1, V, dtype=np.int64)
g = csr_from_edges(V, np.zeros_like(spokes), spokes, symmetrize=True)
md = g.max_degree()
seeds = jnp.asarray(np.arange(0, V, 4, dtype=np.int32))  # every shard seeded
key = jax.random.PRNGKey(3)
mesh = jax.make_mesh((8,), ("data",))
out = {}
ex_entries = {}
for spec in (alg.deepwalk(), alg.metropolis_hastings_walk()):
    ref = random_walk(g, seeds, key, depth=8, spec=spec,
                      max_degree=md, backend="reference")
    # the default budget scales with E and is tiny on a 512-edge star, so
    # the hub leg forces the center in explicitly (1 MiB >> one row's cost)
    for hb, slots, tag in ((0, 2, "nohubs_tiny"), (1 << 20, None, "hubs")):
        kw = dict(exchange_slots=slots) if slots else {}
        res = sharded_random_walk(mesh, g, seeds, key, depth=8, spec=spec,
                                  max_degree=md, backend="reference",
                                  hub_bytes=hb, **kw)
        out[f"{spec.name}/{tag}"] = bool(jnp.array_equal(ref.walks, res.walks))
        ex_entries[f"{spec.name}/{tag}"] = res.stats["exchanged_entries"]
        if tag == "hubs" and spec.name == "deepwalk":
            out["deepwalk/hub_hops"] = res.stats["hub_hops"] > 0
# the exchange-locality claim needs volume: deepwalk migrates every hop
# (spoke->hub->spoke), so replication must cut it strictly; MH on a star
# almost never accepts a move into the hub (accept_p ~ 1/256), so its
# counts are single digits — only require no regression there
reduced = (ex_entries["deepwalk/hubs"] < ex_entries["deepwalk/nohubs_tiny"]
           and ex_entries["mhrw/hubs"] <= ex_entries["mhrw/nohubs_tiny"])
print(json.dumps(dict(out, reduced=reduced, entries=ex_entries)))
""")
    entries = d.pop("entries")
    assert all(v for k, v in d.items()), {**{k: v for k, v in d.items() if not v},
                                          "entries": entries}
