"""Device-resident frontier queues for the out-of-memory scheduler (paper §V).

ThunderRW-style step-interleaved execution and NextDoor-style flat frontier
arrays share one lesson: the frontier must live on the device as fixed-shape
arrays, not per-entry host bookkeeping.  This module provides that data
structure — one fixed-capacity queue per graph partition, stacked as
``(P, cap)`` arrays with a per-partition count — plus the two cursor ops the
§V scheduler needs, both pure cumsum-compaction array programs so they trace
into the drain loop's ``lax.scan``:

- :func:`push_many` — scatter a batch of entries into the queues of their
  owning partitions in ONE vectorized write (the cross-partition
  redistribution step, paper Fig. 8 "insert into the owning partition's
  queue").  Overflow past ``cap`` is dropped and counted.
- :func:`pop_chunk` — take up to ``n`` entries off the front of one
  partition's queue and left-compact the remainder, optionally restricted to
  the head entry's instance (the paper's Fig. 13 per-instance baseline, i.e.
  ``batched=False``).

Entry metadata mirrors the paper's §V-C batched queue entries: vertex,
InstanceID, CurrDepth, plus the predecessor vertex (needed by
prev-dependent biases such as node2vec).  Empty slots hold ``-1``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FrontierQueues:
    """Per-partition frontier queues as stacked flat device arrays.

    vertex/instance/depth/prev: ``(P, cap)`` int32, ``-1`` = empty slot.
    count: ``(P,)`` int32 — live entries per partition (always front-packed).
    dropped: ``()`` int32 — total entries discarded to capacity overflow.
    """

    vertex: jax.Array
    instance: jax.Array
    depth: jax.Array
    prev: jax.Array
    count: jax.Array
    dropped: jax.Array

    def tree_flatten(self):
        return (self.vertex, self.instance, self.depth, self.prev, self.count, self.dropped), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_partitions(self) -> int:
        return self.vertex.shape[0]

    @property
    def capacity(self) -> int:
        return self.vertex.shape[1]

    def total(self) -> jax.Array:
        return jnp.sum(self.count)


def make_queues(num_partitions: int, capacity: int) -> FrontierQueues:
    """Allocate empty queues: ``(P, cap)`` of -1, zero counts."""
    empty = jnp.full((num_partitions, capacity), -1, jnp.int32)
    return FrontierQueues(
        vertex=empty,
        instance=empty,
        depth=empty,
        prev=empty,
        count=jnp.zeros((num_partitions,), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def owner_compaction(pid: jax.Array, valid: jax.Array, num_buckets: int):
    """The cumsum owner-bucketing core shared by queue pushes and the
    mesh-exchange routing (``repro.shard.exchange``).

    A stable sort by owner groups valid entries per bucket in batch order;
    gathers replace scatters throughout (XLA CPU scatter is serialized).
    Returns ``(order, adds, offset)``: the grouping permutation over the
    ``(E,)`` batch, the per-bucket entry counts ``(B,)``, and the start of
    each bucket's group within the sorted batch ``(B,)`` — enough to place
    sorted entry ``order[offset[b] + s]`` at slot ``s`` of bucket ``b``.
    Invalid entries sort last (bucket id ``num_buckets``).
    """
    pidv = jnp.where(valid, pid, num_buckets)
    order = jnp.argsort(pidv)
    adds = jnp.sum(
        (pidv[:, None] == jnp.arange(num_buckets, dtype=pidv.dtype)).astype(jnp.int32),
        axis=0,
    )
    offset = jnp.cumsum(adds) - adds
    return order, adds, offset


def push_many(
    q: FrontierQueues,
    pid: jax.Array,
    vertex: jax.Array,
    instance: jax.Array,
    depth: jax.Array,
    prev: jax.Array,
    valid: jax.Array,
) -> FrontierQueues:
    """Append ``valid`` entries to the tail of their partition's queue.

    All args are flat ``(E,)`` arrays; ``pid`` names the owning partition of
    each entry.  One cumsum over an ``(E, P)`` membership one-hot assigns
    every entry its within-batch rank, so the whole redistribution is a
    single scatter — no per-entry host pushes.  Entries that would land past
    ``cap`` are dropped and counted in ``q.dropped``.
    """
    num_parts, cap = q.vertex.shape
    num_entries = pid.shape[0]
    order, adds, offset = owner_compaction(pid, valid, num_parts)
    # slot (p, s) receives sorted entry offset[p] + (s - count[p]) when that
    # lands inside this batch's group for p; otherwise keeps its old value
    j = jnp.arange(cap, dtype=jnp.int32)[None, :] - q.count[:, None]  # (P, cap)
    fill = (j >= 0) & (j < adds[:, None])
    src = order[jnp.clip(offset[:, None] + j, 0, max(num_entries - 1, 0))]

    def merge(arr, vals):
        return jnp.where(fill, vals[src], arr)

    new_count = jnp.minimum(q.count + adds, cap)
    dropped = q.dropped + jnp.sum(adds) - jnp.sum(new_count - q.count)
    return FrontierQueues(
        vertex=merge(q.vertex, vertex),
        instance=merge(q.instance, instance),
        depth=merge(q.depth, depth),
        prev=merge(q.prev, prev),
        count=new_count,
        dropped=dropped,
    )


def pop_chunk(
    q: FrontierQueues,
    pid: jax.Array,
    n: int,
    limit: jax.Array | None = None,
    match_head_instance: bool = False,
):
    """Pop up to ``n`` entries off the front of queue ``pid``.

    Returns ``((vertex, instance, depth, prev), taken, queues')`` where the
    entry arrays have static shape ``(n,)`` padded with -1 and ``taken`` is
    the realized count.  ``limit`` (dynamic, <= n) caps the take without
    changing shapes — the drain loop's workload-balancing budget.  With
    ``match_head_instance`` only entries of the front entry's instance are
    taken (paper Fig. 13 per-instance baseline).  The surviving entries are
    left-compacted so the queue front stays at column 0.
    """
    cap = q.vertex.shape[1]
    take_n = min(n, cap)
    rows = (q.vertex[pid], q.instance[pid], q.depth[pid], q.prev[pid])
    idx = jnp.arange(cap, dtype=jnp.int32)
    cnt = q.count[pid]
    sel = idx < cnt
    if match_head_instance:
        sel = sel & (rows[1] == rows[1][0])
    rank = jnp.cumsum(sel.astype(jnp.int32)) - 1
    lim = jnp.int32(take_n) if limit is None else jnp.minimum(jnp.int32(limit), take_n)
    take = sel & (rank < lim)
    taken = jnp.sum(take.astype(jnp.int32))
    # one stable sort orders [taken | surviving | empty]; everything else is
    # gathers and a dynamic roll — no scatters (serialized on CPU XLA)
    group = jnp.where(take, 0, jnp.where(idx < cnt, 1, 2))
    perm = jnp.argsort(group)
    nkeep = cnt - taken

    outs = []
    new_rows = []
    for r in rows:
        s = r[perm]
        outs.append(
            jnp.where(
                jnp.arange(n, dtype=jnp.int32) < taken,
                jnp.pad(s[:take_n], (0, n - take_n), constant_values=-1),
                -1,
            )
        )
        new_rows.append(jnp.where(idx < nkeep, jnp.roll(s, -taken), -1))
    new_q = FrontierQueues(
        vertex=q.vertex.at[pid].set(new_rows[0]),
        instance=q.instance.at[pid].set(new_rows[1]),
        depth=q.depth.at[pid].set(new_rows[2]),
        prev=q.prev.at[pid].set(new_rows[3]),
        count=q.count.at[pid].set(nkeep),
        dropped=q.dropped,
    )
    return tuple(outs), taken, new_q
