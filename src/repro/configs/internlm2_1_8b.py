"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297].

Assigned: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
SwiGLU, RoPE, untied output head.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    pattern=("global",),
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    optimizer="adamw",
    microbatches=2,
)

SMOKE = ModelConfig(
    name="internlm2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("global",),
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
