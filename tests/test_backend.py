"""Selection backend dispatcher: reference vs Pallas parity, engine routing.

The dispatcher's counted-RNG contract (``select.retry_randoms``) makes the
kernel path bit-identical to the reference retry loop, so most assertions
here are exact array equality — not statistical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import backend as bk
from repro.core import select as sel
from repro.core.engine import random_walk, traversal_sample
from repro.graph import powerlaw_graph
from repro.graph.csr import csr_from_edges

KEY = jax.random.PRNGKey(0)


def _biases(key, i_dim, p, zero_frac=0.25):
    b = jax.random.uniform(key, (i_dim, p))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (i_dim, p)) > zero_frac
    return b * keep


class TestResolve:
    def test_auto_resolves_by_device(self):
        expect = "pallas" if jax.default_backend() == "tpu" else "reference"
        assert bk.resolve_backend("auto") == expect

    def test_explicit_passthrough(self):
        assert bk.resolve_backend("reference") == "reference"
        assert bk.resolve_backend("pallas") == "pallas"

    def test_unknown_rejected(self):
        with pytest.raises(ValueError):
            bk.resolve_backend("cuda")


class TestWithoutReplacementParity:
    # non-aligned P (lane padding) and non-aligned I (blk_i padding) included
    @pytest.mark.parametrize("i_dim,p,k", [(8, 128, 4), (13, 100, 3), (5, 37, 2), (32, 256, 8)])
    def test_its_brs_bitwise(self, i_dim, p, k):
        key = jax.random.PRNGKey(i_dim * p + k)
        b = _biases(key, i_dim, p)
        mask = jax.random.uniform(jax.random.fold_in(key, 2), (i_dim, p)) > 0.1
        ref = bk.select_without_replacement(
            key, b, mask, k, method="its_brs", backend="reference", max_iters=8
        )
        pal = bk.select_without_replacement(
            key, b, mask, k, method="its_brs", backend="pallas", max_iters=8
        )
        np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(pal.indices))
        np.testing.assert_array_equal(np.asarray(ref.valid), np.asarray(pal.valid))
        np.testing.assert_array_equal(np.asarray(ref.iters), np.asarray(pal.iters))
        np.testing.assert_array_equal(np.asarray(ref.searches), np.asarray(pal.searches))

    def test_gumbel_bitwise(self):
        b = _biases(KEY, 16, 64)
        ref = bk.select_without_replacement(KEY, b, None, 4, method="gumbel", backend="reference")
        pal = bk.select_without_replacement(KEY, b, None, 4, method="gumbel", backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(pal.indices))
        np.testing.assert_array_equal(np.asarray(ref.valid), np.asarray(pal.valid))

    def test_batched_leading_dims(self):
        """(I, fs, P) pools — the per-vertex neighbor-selection shape."""
        b = jax.random.uniform(KEY, (6, 3, 40))
        ref = bk.select_without_replacement(
            KEY, b, None, 2, method="its_brs", backend="reference", max_iters=6
        )
        pal = bk.select_without_replacement(
            KEY, b, None, 2, method="its_brs", backend="pallas", max_iters=6
        )
        assert pal.indices.shape == (6, 3, 2)
        np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(pal.indices))

    def test_insufficient_candidates(self):
        b = jnp.tile(jnp.array([1.0, 2.0, 0.0, 0.0]), (10, 1))
        pal = bk.select_without_replacement(
            KEY, b, None, 4, method="its_brs", backend="pallas", max_iters=8
        )
        assert int(pal.valid.sum(-1).max()) <= 2
        assert not np.isin(np.asarray(pal.indices), [2, 3]).any()


class TestWithReplacementParity:
    @pytest.mark.parametrize("i_dim,p", [(16, 64), (11, 100)])
    def test_k1_bitwise(self, i_dim, p):
        key = jax.random.PRNGKey(i_dim + p)
        b = _biases(key, i_dim, p)
        ref = sel.select_with_replacement(key, b, None, 1)
        pal = bk.select_with_replacement(key, b, None, 1, backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))

    def test_dead_rows_match_reference_degenerate_index(self):
        b = jnp.zeros((4, 16))
        ref = sel.select_with_replacement(KEY, b, None, 1)
        pal = bk.select_with_replacement(KEY, b, None, 1, backend="pallas")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


class TestEngineBackends:
    @pytest.fixture(scope="class")
    def graph(self):
        return powerlaw_graph(256, seed=1, weighted=True)

    def test_walk_fast_path_edges_exist(self, graph):
        seeds = jax.random.randint(KEY, (48,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=8, spec=alg.weighted_random_walk(),
                          max_degree=graph.max_degree(), backend="pallas")
        ip, ind = np.asarray(graph.indptr), np.asarray(graph.indices)
        for row in np.asarray(res.walks):
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert b in ind[ip[a]: ip[a + 1]]

    def test_walk_fast_path_stationary_distribution(self, graph):
        """Same distributional bar as the reference path (deepwalk ∝ degree)."""
        seeds = jax.random.randint(KEY, (1024,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=30, spec=alg.deepwalk(),
                          max_degree=graph.max_degree(), backend="pallas")
        last = np.asarray(res.walks)[:, -1]
        last = last[last >= 0]
        deg = np.asarray(graph.indptr[1:] - graph.indptr[:-1]).astype(float)
        visit = np.bincount(last, minlength=graph.num_vertices).astype(float)
        assert np.corrcoef(visit, deg)[0, 1] > 0.7

    def test_walk_window_bias_bitwise(self, graph):
        """State-dependent bias (node2vec) runs the bucketed WINDOW path on
        both backends (transition programs): the dynamic hook is evaluated
        once in shared jnp, the pick dispatches kernel vs mirror —
        bit-identical."""
        seeds = jax.random.randint(KEY, (32,), 0, graph.num_vertices)
        kw = dict(depth=5, spec=alg.node2vec(), max_degree=graph.max_degree())
        ref = random_walk(graph, seeds, KEY, backend="reference", **kw)
        pal = random_walk(graph, seeds, KEY, backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(ref.walks), np.asarray(pal.walks))

    def test_walk_chunked_huge_degree_cohort(self):
        """A hub above the last bucket segment routes through the two-pass
        chunked scan and still yields real neighbors."""
        hub_deg = bk.WALK_BUCKETS[-1] + 37
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        g = csr_from_edges(hub_deg + 1, src, dst)
        assert g.max_degree() > bk.WALK_BUCKETS[-1]
        seeds = jnp.zeros((8,), jnp.int32)
        res = random_walk(g, seeds, KEY, depth=2, spec=alg.deepwalk(),
                          max_degree=g.max_degree(), backend="pallas")
        walks = np.asarray(res.walks)
        assert (walks[:, 1] >= 1).all() and (walks[:, 1] <= hub_deg).all()
        assert (walks[:, 2] == 0).all()  # spokes all point back at the hub

    def test_walk_understated_max_degree_keeps_hub_walkers(self):
        """Regression: the bucket plan must not shrink its top segment on the
        caller's (possibly understated) max_degree — a deg-400 hub with
        declared max_degree=300 must still walk on the pallas fast path.
        (Only exact=True callers, like the OOM drain planning from the true
        max row degree, opt into the shrink.)"""
        hub_deg = 400
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        g = csr_from_edges(hub_deg + 1, src, dst)
        assert bk.walk_bucket_plan(300) == ((128, 512), False)
        assert bk.walk_bucket_plan(300, exact=True) == ((128, 384), False)
        seeds = jnp.zeros((8,), jnp.int32)
        res = random_walk(g, seeds, KEY, depth=2, spec=alg.deepwalk(),
                          max_degree=300, backend="pallas")
        assert (np.asarray(res.walks)[:, 1] >= 1).all()

    @pytest.mark.parametrize("name", ["neighbor_unbiased", "layer", "mdrw"])
    def test_traversal_bitwise(self, graph, name):
        pools = jax.random.randint(KEY, (8, 2), 0, graph.num_vertices)
        kw = dict(depth=2, spec=alg.ALGORITHMS[name](), max_degree=graph.max_degree(),
                  pool_capacity=64, max_vertices=graph.num_vertices)
        ref = traversal_sample(graph, pools, KEY, backend="reference", **kw)
        pal = traversal_sample(graph, pools, KEY, backend="pallas", **kw)
        for a, b, field in zip(ref, pal, ref._fields):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=field)


def _rank2_trailing_dims(jaxpr, dims):
    """Collect the trailing dim of every rank>=2 aval, recursing into nested
    jaxprs (pjit/scan/cond/pallas_call bodies)."""
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            shape = getattr(getattr(v, "aval", None), "shape", ())
            if len(shape) >= 2:
                dims.append(int(shape[-1]))
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                _rank2_trailing_dims(sub, dims)
    return dims


def _subjaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        return [val.jaxpr]
    if isinstance(val, jax.core.Jaxpr):
        return [val]
    if isinstance(val, (list, tuple)):
        return [j for v in val for j in _subjaxprs(v)]
    return []


class TestTransitionPrograms:
    """The tentpole contract (DESIGN.md §10): node2vec/MH/jump/restart run
    the degree-bucketed fast path on BOTH backends, bit-identically, with no
    dense full-context gather in their jaxpr."""

    @pytest.fixture(scope="class")
    def graph(self):
        return powerlaw_graph(256, seed=1, weighted=True)

    def _specs(self, graph):
        return {
            "node2vec": alg.node2vec(),
            "mhrw": alg.metropolis_hastings_walk(),
            "rw_jump": alg.random_walk_with_jump(0.3, graph.num_vertices),
            "rw_restart": alg.random_walk_with_restart(0.3, home=5),
            "rw_restart_home": alg.random_walk_with_restart(0.3),
        }

    @pytest.mark.parametrize(
        "name", ["node2vec", "mhrw", "rw_jump", "rw_restart", "rw_restart_home"]
    )
    def test_cross_backend_bitwise(self, graph, name):
        spec = self._specs(graph)[name]
        seeds = jax.random.randint(KEY, (48,), 0, graph.num_vertices)
        kw = dict(depth=8, spec=spec, max_degree=graph.max_degree())
        ref = random_walk(graph, seeds, KEY, backend="reference", **kw)
        pal = random_walk(graph, seeds, KEY, backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(ref.walks), np.asarray(pal.walks))
        np.testing.assert_array_equal(np.asarray(ref.lengths), np.asarray(pal.lengths))
        assert int(ref.lengths.min()) == 9  # nobody silently died

    @pytest.mark.parametrize(
        "name", ["node2vec", "mhrw", "rw_jump", "rw_restart", "rw_restart_home"]
    )
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_no_dense_gather_in_jaxpr(self, graph, name, backend):
        """With a declared max_degree far above the bucket windows, the
        bucketed paths must not materialize any (..., max_degree)-wide
        tensor; the widest allowed is the top bucket's 2·512 window.  The
        forced-opaque fallback (transition stripped) does materialize one —
        proof the probe can tell the difference."""
        import dataclasses

        declared = 4096
        spec = self._specs(graph)[name]
        seeds = jax.random.randint(KEY, (16,), 0, graph.num_vertices)

        def dims_of(s):
            jx = jax.make_jaxpr(
                lambda g, sd, k: random_walk(
                    g, sd, k, depth=2, spec=s, max_degree=declared, backend=backend
                )
            )(graph, seeds, KEY)
            return _rank2_trailing_dims(jx.jaxpr, [])

        assert max(dims_of(spec)) <= 2 * bk.WALK_BUCKETS[-1]
        if name != "rw_restart_home":  # restart-to-seed has no legacy hook
            opaque = dataclasses.replace(spec, transition=None, flat_edge_bias=None)
            assert max(dims_of(opaque)) >= declared

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_flat_understated_max_degree_truncates_not_kills(self, backend):
        """Regression: with the bucketed flat path now the default on BOTH
        backends, a hub whose degree exceeds the declared plan entirely
        (deg 600 vs max_degree=256 → buckets (128,512), no chunked tail)
        must truncate its neighborhood to the top cohort's window like the
        dense gather did — not silently die at step 1."""
        from repro.graph.csr import csr_from_edges

        hub_deg = 600
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        g = csr_from_edges(hub_deg + 1, src, dst)
        seeds = jnp.zeros((8,), jnp.int32)
        res = random_walk(g, seeds, KEY, depth=2, spec=alg.deepwalk(),
                          max_degree=256, backend=backend)
        walks = np.asarray(res.walks)
        assert (walks[:, 1] >= 1).all() and (walks[:, 1] <= 512).all()
        assert (walks[:, 2] == 0).all()
        ref = random_walk(g, seeds, KEY, depth=2, spec=alg.deepwalk(),
                          max_degree=256, backend="reference")
        np.testing.assert_array_equal(walks, np.asarray(ref.walks))

    def test_window_understated_max_degree_truncates_not_kills(self):
        """In-memory the window path trusts the caller's max_degree for its
        exact bucket plan; an UNDERSTATED bound must degrade like the dense
        gather it replaced — hub neighborhoods truncate to the top cohort's
        window — never silently kill walkers.  Both backends, bit-identical."""
        from repro.graph.csr import csr_from_edges

        hub_deg = 300  # true degree above the declared 256 plan
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        g = csr_from_edges(hub_deg + 1, src, dst)
        seeds = jnp.zeros((8,), jnp.int32)
        kw = dict(depth=2, spec=alg.node2vec(), max_degree=256)
        ref = random_walk(g, seeds, KEY, backend="reference", **kw)
        pal = random_walk(g, seeds, KEY, backend="pallas", **kw)
        walks = np.asarray(ref.walks)
        assert (walks[:, 1] >= 1).all() and (walks[:, 1] <= 256).all()
        assert (walks[:, 2] == 0).all()  # spokes point back at the hub
        np.testing.assert_array_equal(walks, np.asarray(pal.walks))

    def test_restart_home_returns_to_seed(self, graph):
        """target="home" teleports to each walk's own seed (carried state)."""
        spec = alg.random_walk_with_restart(1.0)
        seeds = jax.random.randint(KEY, (16,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=4, spec=spec,
                          max_degree=graph.max_degree())
        walks = np.asarray(res.walks)
        for i in range(16):
            alive = walks[i, 1:][walks[i, 1:] >= 0]
            assert (alive == walks[i, 0]).all()

    def test_lowering_infers_legacy_flags(self):
        from repro.core import transition as tp
        from repro.core.api import SamplingSpec

        legacy_flat = SamplingSpec(flat_edge_bias=lambda g: g.weights)
        prog = tp.lower(legacy_flat)
        assert isinstance(prog.bias, tp.FlatBias)
        assert isinstance(prog.epilogue, tp.IdentityEpilogue)

        legacy_opaque = SamplingSpec(update=lambda k, c, u: u)
        prog = tp.lower(legacy_opaque)
        assert isinstance(prog.bias, tp.OpaqueBias)
        assert isinstance(prog.epilogue, tp.OpaqueEpilogue)

    def test_declared_program_wins(self):
        from repro.core import transition as tp

        spec = alg.metropolis_hastings_walk()
        prog = tp.lower(spec)
        assert isinstance(prog.bias, tp.FlatBias)
        assert isinstance(prog.epilogue, tp.MHAcceptEpilogue)
        assert not prog.carries_home
        assert alg.random_walk_with_restart(0.5).transition.carries_home


class TestScanTrace:
    def test_traversal_trace_is_depth_independent(self):
        g = powerlaw_graph(64, seed=2, weighted=True)
        pools = jax.random.randint(KEY, (4, 1), 0, g.num_vertices)

        def hlo_len(depth):
            lo = traversal_sample.lower(
                g, pools, KEY, depth=depth, spec=alg.layer_sampling(2, 2),
                max_degree=g.max_degree(), pool_capacity=16,
                max_vertices=g.num_vertices, backend="reference",
            )
            return len(lo.as_text())

        s2, s8 = hlo_len(2), hlo_len(8)
        assert s8 < 1.2 * s2, (s2, s8)


class TestInsertIntoPool:
    def test_compaction_semantics(self):
        from repro.core.engine import _insert_into_pool
        pool = jnp.array([[5, -1, 3, -1], [-1, -1, -1, -1]])
        new = jnp.array([[7, -1, 9], [1, 2, -1]])
        out = np.asarray(_insert_into_pool(pool, new))
        np.testing.assert_array_equal(out[0], [5, 3, 7, 9])
        np.testing.assert_array_equal(out[1], [1, 2, -1, -1])

    def test_overflow_dropped(self):
        from repro.core.engine import _insert_into_pool
        pool = jnp.array([[1, 2, 3]])
        new = jnp.array([[4, 5]])
        out = np.asarray(_insert_into_pool(pool, new))
        np.testing.assert_array_equal(out[0], [1, 2, 3])
