"""Model configuration schema shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture = one frozen config (hashable: usable as a jit static)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # per-layer kind pattern, tiled over the stack; kinds:
    #   "global" full attn | "local" sliding-window attn | "rglru" Griffin
    #   block | "mlstm" / "slstm" xLSTM blocks
    pattern: Tuple[str, ...] = ("global",)
    window_size: int = 0  # sliding window for "local"
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    use_qk_norm: bool = False
    activation: str = "silu"  # gelu | silu | geglu | swiglu | relu
    glu: bool = True  # gated FFN (GeGLU/SwiGLU)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dense_ff: int = 0  # parallel dense-residual FFN (arctic) / shared expert
    capacity_factor: float = 1.25
    router_mode: str = "topk"  # topk | sampled (C-SAW selection machinery)

    # recurrent blocks
    rnn_width: int = 0  # RG-LRU width (defaults to d_model)
    conv1d_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334

    # embeddings / head
    tie_embeddings: bool = True
    emb_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    norm_eps: float = 1e-6
    frontend: str = "none"  # none | audio | vision (stub embeddings)
    frontend_tokens: int = 0  # prefix length provided by the frontend stub

    # numerics / compilation
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    scan_blocks: bool = True
    remat: str = "full"  # full | none
    attn_chunk: int = 1024  # online-softmax KV chunk
    microbatches: int = 1  # gradient accumulation (activation memory / m)
    loss_chunk: int = 512  # chunked-CE sequence block (bigger = fewer head passes)

    # which optimizer the launcher should pick (adafactor for >=100B)
    optimizer: str = "adamw"
    # tensor-parallel mode: "model" (TP over the model axis) or "dp" (remap
    # the model axis to extra data parallelism — small archs where TP is
    # pure collective overhead; EXPERIMENTS.md §Perf xlstm iterations)
    tp_mode: str = "model"
    # dtype of cross-chip partial-sum reductions for row-parallel matmuls
    # ("bf16" halves TP wire bytes vs the f32 default; §Perf gemma-7b it.1)
    reduce_dtype: str = "f32"
    # dtype of materialized attention score blocks ("bf16" halves the HBM
    # traffic that a fused flash kernel would avoid; §Perf gemma-7b it.2)
    attn_scores_dtype: str = "f32"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer kind list: pattern tiled + truncated to num_layers."""
        reps = -(-self.num_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.num_layers])

    @property
    def n_rep(self) -> int:
        """Number of whole pattern repetitions (the scan length)."""
        return self.num_layers // len(self.pattern)

    @property
    def n_tail(self) -> int:
        """Layers beyond the last whole repetition (unrolled)."""
        return self.num_layers - self.n_rep * len(self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, h, kv, hd, f = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim, self.d_ff
        per_layer = 0
        for kind in self.layer_kinds():
            if kind in ("global", "local", "global_dense"):
                per_layer += d * (h + 2 * kv) * hd + h * hd * d  # qkvo
                if self.num_experts and kind != "global_dense":
                    per_layer += d * self.num_experts  # router
                    nmat = 3 if self.glu else 2
                    per_layer += self.num_experts * nmat * d * f
                    if self.moe_dense_ff:
                        per_layer += nmat * d * self.moe_dense_ff
                elif f:
                    per_layer += (3 if self.glu else 2) * d * f
            elif kind == "rglru":
                w = self.rnn_width or d
                per_layer += 2 * d * w + w * self.conv1d_width + 2 * w * w // 1 + w * d
                per_layer += (3 if self.glu else 2) * d * f  # its own MLP
            elif kind == "mlstm":
                up = int(d * self.mlstm_proj_factor)
                per_layer += 2 * d * up + 3 * up * up // max(self.num_heads, 1) + up * d
            elif kind == "slstm":
                per_layer += 4 * d * d + int(d * self.slstm_proj_factor) * d * 2
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return per_layer + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        nmat = 3 if self.glu else 2
        unused = (self.num_experts - self.num_experts_per_tok) * nmat * d * f
        n_moe_layers = sum(
            1 for k in self.layer_kinds() if k == "global"
        )
        return self.param_count() - unused * n_moe_layers
