"""Contiguous vertex-range graph partitioning (paper §V-A).

The paper partitions by assigning a contiguous, equal range of vertices and
*all their neighbor lists* to one partition, because sampling requires every
edge of a vertex to be present to compute transition probabilities, and
because range membership is decidable in O(1) (``vertex // range_size``),
which the workload-aware scheduler relies on.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class RangePartition:
    """One partition: vertices [vertex_lo, vertex_hi) with their full rows."""

    pid: int
    vertex_lo: int
    vertex_hi: int
    # Local CSR over the owned vertex range. indptr is re-based to 0; indices
    # remain *global* vertex ids (edges may point to any partition).
    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray

    @property
    def num_vertices(self) -> int:
        return self.vertex_hi - self.vertex_lo

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes + self.weights.nbytes

    def to_device_csr(self, total_vertices: int) -> CSRGraph:
        """Materialize a device CSR covering the full vertex id space.

        Vertices outside [lo, hi) get empty rows so global vertex ids index
        directly — mirrors the paper keeping global ids in partition queues.
        """
        indptr = np.zeros(total_vertices + 1, dtype=np.int32)
        local = self.indptr.astype(np.int32)
        indptr[self.vertex_lo + 1 : self.vertex_hi + 1] = local[1:]
        indptr[self.vertex_hi + 1 :] = local[-1]
        return CSRGraph(
            indptr=jnp.asarray(indptr),
            indices=jnp.asarray(self.indices, dtype=jnp.int32),
            weights=jnp.asarray(self.weights, dtype=jnp.float32),
        )


def partition_by_vertex_range(graph: CSRGraph, num_partitions: int) -> List[RangePartition]:
    """Split a CSRGraph into ``num_partitions`` contiguous vertex ranges."""
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    weights = np.asarray(graph.weights)
    n = indptr.shape[0] - 1
    bounds = np.linspace(0, n, num_partitions + 1).astype(np.int64)
    parts: List[RangePartition] = []
    for pid in range(num_partitions):
        lo, hi = int(bounds[pid]), int(bounds[pid + 1])
        e_lo, e_hi = int(indptr[lo]), int(indptr[hi])
        local_indptr = (indptr[lo : hi + 1] - indptr[lo]).astype(np.int32)
        parts.append(
            RangePartition(
                pid=pid,
                vertex_lo=lo,
                vertex_hi=hi,
                indptr=local_indptr,
                indices=indices[e_lo:e_hi].copy(),
                weights=weights[e_lo:e_hi].copy(),
            )
        )
    return parts


def partition_of(vertex: np.ndarray | int, num_vertices: int, num_partitions: int):
    """O(1) partition lookup (paper's third reason for range partitioning)."""
    bounds = np.linspace(0, num_vertices, num_partitions + 1).astype(np.int64)
    return np.clip(np.searchsorted(bounds, np.asarray(vertex), side="right") - 1, 0, num_partitions - 1)
