"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see DESIGN.md §9 for the
figure-to-module index).  ``python -m benchmarks.run [--only fig09,...]``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    args = ap.parse_args()

    from benchmarks import (  # noqa: PLC0415
        bench_serve,
        bench_walk,
        fig09_seps,
        fig10_inmem,
        fig13_oom,
        fig16_sweep,
        fig17_scaling,
        roofline,
    )

    modules = {
        "fig09": fig09_seps,
        "fig10": fig10_inmem,  # also emits fig11/fig12 rows
        "fig13": fig13_oom,  # also emits fig14/fig15 columns
        "fig16": fig16_sweep,
        "fig17": fig17_scaling,
        "roofline": roofline,
        "walk": bench_walk,  # transition programs; writes BENCH_walk.json
        "serve": bench_serve,  # batched request serving; writes BENCH_serve.json
    }
    keys = args.only.split(",") if args.only else list(modules)
    print("name,us_per_call,derived")
    ok = True
    for k in keys:
        try:
            for r in modules[k].run():
                print(r, flush=True)
        except Exception as e:  # noqa: BLE001
            ok = False
            print(f"{k},0.0,ERROR={type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
