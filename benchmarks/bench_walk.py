"""Walk-engine benchmark: transition programs on the fast path → BENCH_walk.json.

Sweeps {deepwalk, node2vec, mhrw, rw_restart} × {reference, pallas} ×
{in-memory, out-of-memory} on the pl50k benchmark graph, plus the
forced-opaque node2vec configuration (transition program stripped, i.e. the
pre-transition-program dense full-context gather) so the headline number —
the bucketed dynamic-bias path vs the dense gather it replaced — is measured
PR-over-PR, and the adaptive-selection serving comparison (DESIGN.md §13):
a static-bias serving workload through the SamplingService with the
selection method pinned to "its" vs "alias" (forced — the cost model
auto-picks rejection for deepwalk's uniform bias), tables prebuilt via
``prewarm()``, whose ratio is the alias-table amortization headline.

Every row is tagged ``pallas_interpret``; on non-TPU hosts interpret-mode
Pallas rows measure the interpreter, not the kernel, so they are SKIPPED by
default (``--include-interpret`` restores them; ``--skip-interpret`` forces
the skip even on TPU).  The cross-cutting numbers are reference-vs-reference
on any host and the kernel ratios on TPU.

Usage:  PYTHONPATH=src python benchmarks/bench_walk.py [--iters 3]
        [--skip-interpret | --include-interpret]
(also exposed as ``run()`` rows through benchmarks/run.py, which skips
interpret-mode rows by default on CPU)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import BENCH_GRAPHS, row, timeit  # noqa: E402

from repro.core import algorithms as alg  # noqa: E402
from repro.core import backend as bk  # noqa: E402
from repro.core import methods as mt  # noqa: E402
from repro.core import transition as tp  # noqa: E402
from repro.core.engine import flat_method_plan, random_walk  # noqa: E402
from repro.core.oom import oom_random_walk  # noqa: E402
from repro.graph.partition import partition_by_vertex_range  # noqa: E402
from repro.serve.service import SamplingService  # noqa: E402

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_walk.json"

GRAPH = "pl50k"
WALKERS = 1024
DEPTH = 8
OOM_PARTS = 4
OOM_CHUNK = 1024
SERVE_REQUESTS = 4
KEY = jax.random.PRNGKey(0)


def _specs(g):
    n2v = alg.node2vec()
    return {
        "deepwalk": alg.deepwalk(),
        "node2vec": n2v,
        # the pre-PR dense full-context gather: same hooks, program stripped
        "node2vec_gather": dataclasses.replace(n2v, transition=None),
        "mhrw": alg.metropolis_hastings_walk(),
        "rw_restart": alg.random_walk_with_restart(0.15),
    }


def _method_plans(g, specs):
    """Auto-picked per-cohort selection methods for every flat-bias spec."""
    md = g.max_degree()
    buckets, use_chunked = bk.walk_bucket_plan(md)
    plans = {}
    for name, spec in specs.items():
        program = tp.lower(spec)
        if program.mode != "flat":
            continue
        methods, _ = flat_method_plan(g, program, md)
        plans[name] = mt.describe_plan(methods, buckets, use_chunked)
    return plans


def bench_inmem(g, spec, backend, iters):
    seeds = jax.random.randint(KEY, (WALKERS,), 0, g.num_vertices)
    md = g.max_degree()

    def fn(graph, seeds, key):
        return random_walk(
            graph, seeds, key, depth=DEPTH, spec=spec, max_degree=md, backend=backend
        ).walks

    return timeit(fn, g, seeds, KEY, warmup=1, iters=iters)


def bench_oom(g, spec, backend, iters):
    parts = partition_by_vertex_range(g, OOM_PARTS)
    seeds = np.random.default_rng(0).integers(0, g.num_vertices, WALKERS)
    md = g.max_degree()

    def fn():
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, KEY, depth=DEPTH, spec=spec,
            max_degree=md, memory_capacity=2, chunk=OOM_CHUNK, backend=backend)
        return walks

    # oom_random_walk blocks internally (host scheduling loop)
    return timeit(lambda: jax.numpy.asarray(fn()), warmup=1, iters=iters)


def bench_serving(g, selection_method, iters):
    """A static-bias serving workload with the selection method PINNED.

    ``SERVE_REQUESTS`` deepwalk requests per drain through one
    SamplingService, tables prebuilt with ``prewarm()`` so every drain
    reuses them — the amortization the adaptive runtime exists for.
    Reference backend: the ratio must hold without kernel help.
    """
    spec = dataclasses.replace(alg.deepwalk(), selection_method=selection_method)
    svc = SamplingService(g, backend="reference", key=jax.random.PRNGKey(3))
    svc.prewarm(spec)
    rng = np.random.default_rng(2)
    seed_sets = [
        rng.integers(0, g.num_vertices, WALKERS) for _ in range(SERVE_REQUESTS)
    ]

    def fn():
        for s in seed_sets:
            svc.submit(s, depth=DEPTH, spec=spec)
        out = svc.drain()
        return jax.numpy.asarray(next(iter(out.values())).walks)

    return timeit(fn, warmup=1, iters=iters)


def run(iters: int = 3, skip_interpret: bool | None = None):
    g = BENCH_GRAPHS[GRAPH]()
    on_tpu = jax.default_backend() == "tpu"
    if skip_interpret is None:
        skip_interpret = not on_tpu  # interpret-mode rows measure the interpreter
    specs = _specs(g)
    method_plans = _method_plans(g, specs)
    results = []
    for name, spec in specs.items():
        for backend in ("reference", "pallas"):
            interp = backend == "pallas" and not on_tpu
            if interp and skip_interpret:
                continue
            for mode, bench in (("inmem", bench_inmem), ("oom", bench_oom)):
                if name == "node2vec_gather" and mode == "oom":
                    continue  # the dense OOM gather at pl50k degrees is pathological
                if backend == "pallas" and mode == "oom" and not on_tpu:
                    continue  # interpret-mode kernels in the drain loop: minutes
                secs = bench(g, spec, backend, iters)
                r = {
                    "graph": GRAPH, "algo": name, "mode": mode,
                    "backend": backend, "seconds": secs,
                    "pallas_interpret": interp,
                }
                if mode == "inmem" and name in method_plans:
                    r["methods"] = method_plans[name]
                results.append(r)
                yield row(f"walk_{name}_{mode}_{backend}", secs * 1e6,
                          f"walkers={WALKERS};depth={DEPTH}")

    by = {(r["algo"], r["mode"], r["backend"]): r["seconds"] for r in results}
    speedup = by[("node2vec_gather", "inmem", "reference")] / by[("node2vec", "inmem", "reference")]
    results.append({
        "graph": GRAPH, "algo": "node2vec", "mode": "inmem",
        "derived": "bucketed_vs_gather_speedup_reference", "speedup": speedup,
    })
    yield row("walk_node2vec_bucketed_vs_gather", 0.0, f"speedup={speedup:.2f}x")

    # -- adaptive selection: pinned-method serving comparison (§13) ---------
    serve_secs = {}
    for m in ("its", "alias", "rejection"):
        secs = bench_serving(g, m, iters)
        serve_secs[m] = secs
        results.append({
            "graph": GRAPH, "algo": "deepwalk", "mode": "serve",
            "backend": "reference", "selection_method": m, "seconds": secs,
            "pallas_interpret": False,
        })
        yield row(f"walk_serve_deepwalk_{m}", secs * 1e6,
                  f"requests={SERVE_REQUESTS};walkers={WALKERS}")
    alias_speedup = serve_secs["its"] / serve_secs["alias"]
    results.append({
        "graph": GRAPH, "algo": "deepwalk", "mode": "serve",
        "derived": "alias_vs_its_speedup", "speedup": alias_speedup,
    })
    yield row("walk_serve_alias_vs_its", 0.0, f"speedup={alias_speedup:.2f}x")

    OUT_PATH.write_text(json.dumps({
        # shared benchmark-JSON schema (DESIGN.md §9): diffable PR-over-PR
        "bench": "walk",
        "device": jax.default_backend(),
        "pallas_interpret": not on_tpu,
        "skip_interpret": skip_interpret,
        "graph": GRAPH, "walkers": WALKERS, "depth": DEPTH,
        "method_plans": method_plans,
        "results": results,
    }, indent=2))
    yield row("walk_json", 0.0, str(OUT_PATH.name))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--skip-interpret", dest="skip_interpret",
                    action="store_true", default=None,
                    help="skip interpret-mode Pallas rows (default on non-TPU)")
    ap.add_argument("--include-interpret", dest="skip_interpret",
                    action="store_false",
                    help="time interpret-mode Pallas rows anyway")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(args.iters, skip_interpret=args.skip_interpret):
        print(r, flush=True)


if __name__ == "__main__":
    main()
