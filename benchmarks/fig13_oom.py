"""Paper Figs. 13-15: out-of-memory sampling optimizations → BENCH_oom.json.

Configurations (cumulative, as in the paper):
  base   — per-instance processing, round-robin partitions, no balancing
  +BA    — batched multi-instance sampling (§V-C)
  +WS    — workload-aware partition scheduling (§V-B)
  +BAL   — thread-block workload balancing (proportional budgets)
Reported: wall time, kernel launches, partition transfers (Fig. 15) and
kernel workload std (Fig. 14).  Besides the CSV rows, ``run()`` writes
``BENCH_oom.json`` (same schema as ``BENCH_select.json``) so the §V
ablation trajectory is tracked across PRs.

Usage:  PYTHONPATH=src python benchmarks/fig13_oom.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import BENCH_GRAPHS, row  # noqa: E402

from repro.core import algorithms as alg  # noqa: E402
from repro.core.oom import oom_random_walk  # noqa: E402
from repro.graph.partition import partition_by_vertex_range  # noqa: E402

CONFIGS = {
    "base": dict(batched=False, workload_aware=False, balance=False),
    "+BA": dict(batched=True, workload_aware=False, balance=False),
    "+BA+WS": dict(batched=True, workload_aware=True, balance=False),
    "+BA+WS+BAL": dict(batched=True, workload_aware=True, balance=True),
}

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_oom.json"


def run() -> list[str]:
    rows = []
    results = []
    g = BENCH_GRAPHS["pl50k"]()
    md = min(g.max_degree(), 512)
    parts = partition_by_vertex_range(g, 8)
    seeds = np.random.default_rng(0).integers(0, g.num_vertices, 2000)
    key = jax.random.PRNGKey(2)
    base_time = None
    for cname, kw in CONFIGS.items():
        t0 = time.perf_counter()
        walks, stats = oom_random_walk(
            parts, g.num_vertices, seeds, key, depth=16,
            spec=alg.biased_random_walk(), max_degree=md,
            memory_capacity=2, num_streams=2, chunk=1024, **kw,
        )
        secs = time.perf_counter() - t0
        if base_time is None:
            base_time = secs
        rows.append(row(
            f"fig13/{cname}", secs * 1e6,
            f"speedup={base_time/secs:.2f}x;kernels={stats.kernel_launches};"
            f"transfers={stats.partition_transfers};ktime_std={stats.kernel_time_std():.1f};"
            f"SEPS={stats.sampled_edges/secs:.3e}",
        ))
        results.append({
            "config": cname,
            "seconds": secs,
            "speedup_vs_base": base_time / secs,
            "kernel_launches": stats.kernel_launches,
            "partition_transfers": stats.partition_transfers,
            "kernel_workload_std": stats.kernel_time_std(),
            "sampled_edges_per_s": stats.sampled_edges / secs,
            "frontier_dropped": stats.frontier_dropped,
        })
    from repro.core.backend import resolve_backend

    payload = {
        "bench": "fig13 out-of-memory walk ablation (pl50k, 8 partitions)",
        "device": jax.default_backend(),
        "backend": resolve_backend("auto"),  # what oom_random_walk actually ran
        "pallas_interpret": resolve_backend("auto") == "pallas" and jax.default_backend() != "tpu",
        "results": results,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


def main() -> None:
    for r in run():
        print(r)
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
