"""Roofline table builder: reads results/dryrun/*/*.json into the
EXPERIMENTS.md §Roofline table (terms in seconds, dominant bottleneck,
useful-flop ratio, fix-it note)."""
from __future__ import annotations

import glob
import json
import os

FIX_NOTES = {
    "compute_s": "raise arithmetic intensity: larger per-chip tiles / fewer remat recomputes",
    "memory_s": "cut HBM traffic: fuse, shrink saved activations (microbatch/remat policy), bf16 collaterals",
    "collective_s": "cut wire bytes: RS+AG instead of AR (seq-parallel TP), bf16 reduce, overlap with compute",
}


def load(mesh_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(mesh_dir: str) -> str:
    recs = load(mesh_dir)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful | fits | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — | {r['skip_reason'][:50]} |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | — | {r['error'][:50]} |"
            )
            continue
        t = r["roofline"]
        dom = r["dominant"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | {t['memory_s']:.3g} | "
            f"{t['collective_s']:.3g} | {dom.replace('_s','')} | {r['model_flops']:.2e} | "
            f"{r['useful_flop_ratio']:.2f} | {'y' if r['fits_hbm'] else 'N'} | {FIX_NOTES[dom][:60]} |"
        )
    return "\n".join(lines)


def run() -> list[str]:
    rows = []
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join("results", "dryrun", mesh)
        if not os.path.isdir(d):
            continue
        ok = sum(1 for r in load(d) if r["status"] == "ok")
        skip = sum(1 for r in load(d) if r["status"] == "skip")
        rows.append(f"roofline/{mesh},0.0,cells_ok={ok};skipped={skip}")
    return rows


if __name__ == "__main__":
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join("results", "dryrun", mesh)
        if os.path.isdir(d):
            print(f"\n## {mesh}\n")
            print(table(d))
