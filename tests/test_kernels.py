"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes and assert_allclose against ref.py.
Integer outputs must match the oracle EXACTLY (same random budget).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import powerlaw_graph
from repro.kernels import ops
from repro.kernels.its_select import its_select_pallas
from repro.kernels.ref import its_select_ref, walk_step_ref
from repro.kernels.walk_step import pad_csr_for_kernel, walk_step_pallas


class TestItsSelectKernel:
    @pytest.mark.parametrize("i_dim,p,k,iters", [
        (8, 64, 2, 4),
        (16, 128, 4, 8),
        (8, 256, 8, 8),
        (32, 100, 3, 6),   # non-lane-aligned pool
        (8, 2048, 4, 8),   # max pool tile
    ])
    def test_matches_ref(self, i_dim, p, k, iters):
        key = jax.random.PRNGKey(i_dim * p + k)
        b = jax.random.uniform(key, (i_dim, p))
        b = b * (jax.random.uniform(jax.random.fold_in(key, 1), (i_dim, p)) > 0.2)
        r = jax.random.uniform(jax.random.fold_in(key, 2), (i_dim, iters, k))
        out_k = its_select_pallas(b, r, blk_i=8)
        out_r = its_select_ref(b, r)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(5)
        b = jax.random.uniform(key, (16, 128)).astype(dtype)
        r = jax.random.uniform(jax.random.fold_in(key, 1), (16, 8, 4))
        out_k = its_select_pallas(b, r, blk_i=8)
        out_r = its_select_ref(b, r)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_no_duplicates_and_valid(self):
        key = jax.random.PRNGKey(6)
        b = jax.random.uniform(key, (64, 256)) + 0.01
        idx = ops.its_select(key, b, 8, iters=12)
        arr = np.asarray(idx)
        assert (arr >= 0).all()
        for row in arr:
            assert len(set(row.tolist())) == len(row)

    def test_skewed_bias_distribution(self):
        """Kernel selections follow transition probabilities (first draw)."""
        key = jax.random.PRNGKey(7)
        b = jnp.tile(jnp.array([8.0, 4.0, 2.0, 1.0, 1.0] + [0.0] * 59), (4096, 1))
        idx = ops.its_select(key, b, 2)
        first = np.asarray(idx[:, 0])
        counts = np.bincount(first, minlength=5)[:5].astype(float)
        probs = np.array([8, 4, 2, 1, 1]) / 16.0
        n = counts.sum()
        chi2 = np.sum((counts - probs * n) ** 2 / (probs * n))
        assert chi2 < 18.5


class TestWalkStepKernel:
    @pytest.mark.parametrize("max_seg,nv", [(64, 128), (128, 256), (256, 512)])
    def test_matches_ref(self, max_seg, nv):
        g = powerlaw_graph(nv, seed=max_seg, weighted=True)
        assert g.max_degree() <= max_seg, "test graph exceeds segment cap"
        key = jax.random.PRNGKey(max_seg)
        cur = jax.random.randint(key, (64,), 0, nv)
        starts = g.indptr[cur]
        degs = g.indptr[cur + 1] - starts
        inds, wts = pad_csr_for_kernel(g.indices, g.weights, max_seg)
        rand = jax.random.uniform(jax.random.fold_in(key, 1), (64,))
        out_k = walk_step_pallas(starts, degs, inds, wts, rand, max_seg=max_seg)
        out_r = walk_step_ref(starts, degs, inds, wts, rand, max_seg)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    @pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
    def test_weight_dtypes(self, wdtype):
        g = powerlaw_graph(128, seed=3, weighted=True)
        key = jax.random.PRNGKey(9)
        cur = jax.random.randint(key, (32,), 0, 128)
        starts = g.indptr[cur]
        degs = g.indptr[cur + 1] - starts
        inds, wts = pad_csr_for_kernel(g.indices, g.weights.astype(wdtype), 64)
        rand = jax.random.uniform(jax.random.fold_in(key, 1), (32,))
        out_k = walk_step_pallas(starts, degs, inds, wts.astype(jnp.float32), rand, max_seg=64)
        out_r = walk_step_ref(starts, degs, inds, wts.astype(jnp.float32), rand, 64)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_next_vertices_are_neighbors(self):
        g = powerlaw_graph(256, seed=4, weighted=True)
        key = jax.random.PRNGKey(11)
        cur = jax.random.randint(key, (128,), 0, 256)
        nxt = np.asarray(ops.walk_step(key, g, cur, max_seg=64))
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        for c, n in zip(np.asarray(cur), nxt):
            if n >= 0:
                assert n in ind[ip[c] : ip[c + 1]]

    def test_dead_end_returns_minus_one(self):
        import repro.graph.csr as csr
        import numpy as onp
        # vertex 0 has no out edges
        g = csr.csr_from_edges(4, onp.array([1, 2, 3]), onp.array([2, 3, 1]))
        key = jax.random.PRNGKey(12)
        nxt = ops.walk_step(key, g, jnp.array([0, 1], jnp.int32), max_seg=64)
        assert int(nxt[0]) == -1 and int(nxt[1]) >= 0
