"""Loop-aware HLO cost analysis for the roofline (DESIGN.md §7).

XLA's ``compiled.cost_analysis()`` visits every instruction ONCE — a
``lax.scan`` over 24 layers contributes its body cost a single time, so
FLOPs/bytes/collectives of scanned models are undercounted by the trip
count.  This module re-derives costs from the optimized HLO text with
**while-loop trip-count multipliers**:

  1. parse computations + instructions (shapes, ops, operands);
  2. find ``while`` ops, extract trip counts from their condition
     computations (``compare(iv, constant(N))`` pattern);
  3. propagate multipliers through nested while bodies;
  4. sum per-instruction costs × multiplier:
       - flops: ``dot`` = 2·prod(result)·prod(contracting dims)
       - bytes: fusion/dot/collective = operand bytes + result bytes
       - collective bytes per op type (ring-factor wire bytes).

Validated against ``cost_analysis()`` on loop-free modules in
tests/test_hlo_analysis.py (within 2%).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|token|[a-z]\d?[\w]*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(shape_str: str) -> list:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\(.*?\)|[\w\[\],{}\s/]+?))\s*([\w\-]+)\((.*)$"
)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        # operand names: %foo references up to the metadata/attr section
        args = rest.split("), ")[0]
        operands = re.findall(r"%([\w.\-]+)", args)
        cur.instrs[name] = Instr(name, shape.strip(), op, operands, line)
        cur.order.append(name)
    return comps


_KNOWN_TRIP = re.compile(r'"known_trip_count":\s*\{"n":"(\d+)"\}')


def _trip_count(cond: Computation) -> int:
    """Extract the loop bound from a while condition computation."""
    consts = {}
    for ins in cond.instrs.values():
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    # find the compare; bound is its constant operand
    for ins in cond.instrs.values():
        if ins.op == "compare" or "compare" in ins.raw:
            for o in ins.operands:
                if o in consts and consts[o] > 0:
                    return consts[o]
        if ins.op == "fusion":
            # compare hidden in a fused computation: fall back to max const
            pass
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else 1


def _multipliers(comps: Dict[str, Computation]) -> tuple:
    """Execution-count multiplier per computation (nested whiles compose).

    Also returns {body_name: trip_count} for while bodies, used to discount
    stacked scan-residual reads (a (n, ...) buffer sliced once per
    iteration transfers its bytes once per sweep, not n times)."""
    mult = {name: 0.0 for name in comps}
    body_trip: Dict[str, int] = {}
    entry = None
    for name in comps:
        # heuristics: the entry computation is the one never referenced
        entry = name
    referenced = set()
    for c in comps.values():
        for ins in c.instrs.values():
            for attr in ("body=", "condition=", "calls=", "to_apply=", "branch_computations="):
                if attr in ins.raw:
                    for r in re.findall(attr.rstrip("=") + r"=%?([\w.\-]+)", ins.raw):
                        referenced.add(r)
                    for r in re.findall(r"\{%?([\w.\-]+)(?:, %?([\w.\-]+))*\}", ins.raw):
                        pass
    entries = [n for n in comps if n not in referenced]
    work = [(e, 1.0) for e in entries]
    while work:
        name, m = work.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for ins in comp.instrs.values():
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                kt = _KNOWN_TRIP.search(ins.raw)
                if kt:
                    n = int(kt.group(1))
                elif cm and cm.group(1) in comps:
                    n = _trip_count(comps[cm.group(1)])
                else:
                    n = 1
                if bm:
                    work.append((bm.group(1), m * n))
                    body_trip[bm.group(1)] = n
                if cm:
                    work.append((cm.group(1), m * (n + 1)))
            elif ins.op in ("fusion", "call", "custom-call", "reduce", "sort", "map", "scatter", "select-and-scatter", "reduce-window"):
                for r in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.raw):
                    work.append((r, m))
            elif ins.op == "conditional":
                for r in re.findall(r"%?([\w.\-]+)", ins.raw.split("branch_computations=")[-1].split("}")[0]) if "branch_computations=" in ins.raw else []:
                    work.append((r, m))
    return mult, body_trip


def _dot_flops(comp: Computation, ins: Instr) -> float:
    """2 × prod(result dims) × prod(contracting dims of lhs)."""
    res = 1
    for d in _first_shape_dims(ins.shape):
        res *= d
    lhs_shape: list = []
    if ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None:
            lhs_shape = _first_shape_dims(lhs.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    contract = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d:
                idx = int(d)
                if idx < len(lhs_shape):
                    contract *= lhs_shape[idx]
    return 2.0 * res * contract


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    The signature drifted: older releases return a per-device LIST of dicts
    (one entry per addressable device), newer ones return the dict directly.
    Validation code (tests, roofline) should depend on this wrapper, not on
    whichever shape the installed JAX happens to produce.
    """
    ca = compiled.cost_analysis()
    if ca is None:  # some backends report nothing — keep the old `or {}` guard
        return {}
    if isinstance(ca, (list, tuple)):
        if not ca:
            return {}
        ca = ca[0]
    return dict(ca)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: Dict[str, dict] = dataclasses.field(default_factory=dict)
    while_count: int = 0

    @property
    def wire_bytes(self) -> float:
        factor = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                  "all-to-all": 1.0, "collective-permute": 1.0}
        return sum(v["bytes"] * factor.get(k, 1.0) for k, v in self.collectives.items())


# ops that move no data (metadata / aliasing views)
_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "rng-get-and-update-state", "domain",
    "get-dimension-size", "opt-barrier", "optimization-barrier",
}


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    mult, body_trip = _multipliers(comps)
    cost = HloCost(collectives={c: {"count": 0, "bytes": 0.0} for c in _COLLECTIVES})

    def shape_bytes_discounted(shape_str: str, trip: int) -> float:
        """Bytes for one use, discounting stacked scan residuals: a buffer
        whose leading dim equals the enclosing trip count is sliced per
        iteration → full transfer once per sweep (1/trip per iteration)."""
        total = 0.0
        for dt, dims_s in _SHAPE_RE.findall(shape_str):
            if dt not in _DTYPE_BYTES:
                continue
            dims = [int(d) for d in dims_s.split(",") if d]
            n = 1
            for d in dims:
                n *= d
            b = float(n * _DTYPE_BYTES[dt])
            if trip > 1 and dims and dims[0] == trip:
                b /= trip
            total += b
        return total

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        trip = body_trip.get(cname, 1)

        def io_bytes(ins) -> float:
            b = shape_bytes_discounted(ins.shape, trip)
            for o in ins.operands:
                if o in comp.instrs:
                    b += shape_bytes_discounted(comp.instrs[o].shape, trip)
            return b

        for ins in comp.instrs.values():
            if ins.op == "while":
                cost.while_count += 1
                continue
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if not ins.op.endswith("-done"):
                    b = _shape_bytes(ins.shape)
                    if ins.op.endswith("-start"):
                        b = b / 2  # start ops carry (operand, result) tuples
                    cost.collectives[base]["count"] += int(m)
                    cost.collectives[base]["bytes"] += m * b
                continue
            if ins.op in ("dot", "convolution"):
                cost.flops += m * _dot_flops(comp, ins)
                cost.bytes_accessed += m * io_bytes(ins)
            elif ins.op == "fusion":
                # fusion reads operands, writes result; dots inside the
                # called computation are credited via the calls= multiplier
                cost.bytes_accessed += m * io_bytes(ins)
            elif ins.op in ("gather", "scatter", "sort", "reduce", "reduce-window"):
                # genuinely memory-moving ops that survive TPU fusion too.
                # Deliberately NOT counted: copy/transpose/slice/elementwise —
                # XLA:CPU materializes them but Mosaic/TPU fuses them into
                # neighboring kernels; counting them would model the CPU
                # backend's fusion granularity, not the TPU target's.
                cost.bytes_accessed += m * io_bytes(ins)
    return cost
