"""GraphSAINT-style GCN training on C-SAW sampled subgraphs.

The paper's own downstream partner (§VI compares against GraphSAINT):
sample subgraphs with the C-SAW engine (MDRW / frontier sampling, the
GraphSAINT random-walk sampler), train a 2-layer GCN on each sampled
subgraph, evaluate on the full graph.  Task: community detection on a
planted-partition (SBM) graph.

    PYTHONPATH=src python examples/graphsaint_gcn.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core.engine import traversal_sample
from repro.graph.csr import csr_from_edges


def sbm_graph(n=1200, k=4, p_in=0.06, p_out=0.002, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, n)
    src, dst = [], []
    for c in range(k):
        idx = np.where(labels == c)[0]
        m = rng.random((len(idx), len(idx))) < p_in
        s, d = np.where(np.triu(m, 1))
        src += list(idx[s]); dst += list(idx[d])
    m = rng.random((n, n)) < p_out
    s, d = np.where(np.triu(m, 1))
    keep = labels[s] != labels[d]
    src += list(s[keep]); dst += list(d[keep])
    g = csr_from_edges(n, np.array(src), np.array(dst), symmetrize=True)
    return g, labels


def gcn_forward(params, adj_norm, x):
    h = adj_norm @ (x @ params["w1"])
    h = jax.nn.relu(h)
    return adj_norm @ (h @ params["w2"])


def norm_adj(g, nodes=None):
    """Symmetric-normalized dense adjacency (small graphs)."""
    n = g.num_vertices
    a = np.zeros((n, n), np.float32)
    ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
    for v in range(n):
        a[v, ind[ip[v]:ip[v+1]]] = 1.0
    a += np.eye(n, dtype=np.float32)
    d = a.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(d, 1))
    return jnp.asarray(a * dinv[:, None] * dinv[None, :])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--instances", type=int, default=16)
    args = ap.parse_args()

    g, labels = sbm_graph()
    n, k = g.num_vertices, labels.max() + 1
    print(f"SBM graph: V={n} E={g.num_edges} classes={k}")
    feat_dim = 32
    rng = np.random.default_rng(1)
    # node features: noisy class signal
    feats = rng.normal(0, 1, (n, feat_dim)).astype(np.float32)
    feats[:, :4] += np.eye(4, dtype=np.float32)[labels] * 1.5
    x_full = jnp.asarray(feats)
    y_full = jnp.asarray(labels)
    adj_full = norm_adj(g)

    key = jax.random.PRNGKey(0)
    params = {
        "w1": jax.random.normal(key, (feat_dim, 64)) * 0.1,
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (64, int(k))) * 0.1,
    }
    spec = alg.multi_dimensional_random_walk(frontier_size=1)
    md = int(g.max_degree())

    @jax.jit
    def train_round(params, node_mask, kkey):
        def loss_fn(p):
            logits = gcn_forward(p, adj_full, x_full)
            ce = -jax.nn.log_softmax(logits)[jnp.arange(n), y_full]
            return jnp.sum(ce * node_mask) / jnp.maximum(node_mask.sum(), 1)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        return jax.tree_util.tree_map(lambda p, gr: p - 0.1 * gr, params, grads), loss

    for r in range(args.rounds):
        kkey = jax.random.fold_in(key, r)
        pools = jax.random.randint(kkey, (args.instances, 8), 0, n)
        res = traversal_sample(g, pools, kkey, depth=24, spec=spec,
                               max_degree=md, pool_capacity=16)
        # union of sampled vertices = GraphSAINT minibatch mask
        nodes = np.unique(np.concatenate([
            np.asarray(res.edges_src).ravel(), np.asarray(res.edges_dst).ravel()]))
        nodes = nodes[nodes >= 0]
        mask = np.zeros(n, np.float32)
        mask[nodes] = 1.0
        params, loss = train_round(params, jnp.asarray(mask), kkey)
        if r % 10 == 0:
            logits = gcn_forward(params, adj_full, x_full)
            acc = float((jnp.argmax(logits, -1) == y_full).mean())
            print(f"round {r:3d} sampled_nodes={len(nodes):4d} loss={float(loss):.3f} acc={acc:.3f}")
    logits = gcn_forward(params, adj_full, x_full)
    acc = float((jnp.argmax(logits, -1) == y_full).mean())
    print(f"final full-graph accuracy: {acc:.3f}")
    assert acc > 0.6, "GCN failed to learn from sampled subgraphs"


if __name__ == "__main__":
    main()
