"""Walk corpus: the C-SAW engine as the LM data plane (DESIGN.md §4).

DeepWalk/node2vec walks over a graph become token sequences for any of the
assigned decoder architectures (vertex id = token id).  This is the honest
integration of the paper's contribution with the LM substrate: the sampler
feeds the trainer, exactly like DeepWalk feeds skip-gram — generalized to
modern decoders.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core.engine import random_walk
from repro.graph.csr import CSRGraph


def build_walk_corpus(
    graph: CSRGraph,
    *,
    num_walks: int,
    walk_length: int,
    algorithm: str = "deepwalk",
    seed: int = 0,
    max_degree: int | None = None,
    vocab_size: int | None = None,
    **algo_kwargs,
) -> np.ndarray:
    """Generate (num_walks, walk_length+1) token sequences via C-SAW.

    Dead-end walks are padded by repeating the last vertex (decoders need
    dense rows); vocab_size asserts vertex ids fit the LM embedding.
    """
    spec = alg.ALGORITHMS[algorithm](**algo_kwargs)
    key = jax.random.PRNGKey(seed)
    seeds = jax.random.randint(
        jax.random.fold_in(key, 1), (num_walks,), 0, graph.num_vertices
    )
    md = max_degree or graph.max_degree()
    res = random_walk(graph, seeds, key, depth=walk_length, spec=spec, max_degree=md)
    # np.asarray of a device array is a read-only view — copy before editing
    walks = np.array(res.walks)
    # pad dead ends by forward-filling the last valid vertex (vectorized;
    # column 0 is always a seed, so every row has a fill source)
    col = np.where(walks < 0, 0, np.arange(walks.shape[1]))
    walks = np.take_along_axis(walks, np.maximum.accumulate(col, axis=1), axis=1)
    if vocab_size is not None:
        assert walks.max() < vocab_size, "graph vertices exceed LM vocab"
    return walks.astype(np.int32)
