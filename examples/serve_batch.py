"""Batched serving demo: prefill + decode with the KV/state cache.

Loads a smoke-scale model (any of the 10 assigned archs), prefills a batch
of prompts token-by-token, then decodes continuations with the jitted
serve step — same code path the decode_32k / long_500k dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-1b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import decode_step, init_cache, init_params
from repro.train.train_step import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    max_len = args.prompt_len + args.tokens
    serve, _ = make_serve_step(cfg, mesh, batch=args.batch, max_len=max_len)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = init_cache(cfg, args.batch, max_len)

    # prefill: feed prompt tokens through the decode path (recurrent archs
    # have O(1) state; attention archs fill the KV cache)
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = serve(params, cache, prompts[:, t : t + 1])
    prefill_s = time.perf_counter() - t0

    # decode: greedy continuation
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0
    seqs = np.concatenate(out, axis=1)
    tput = args.batch * (args.tokens - 1) / decode_s
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s*1e3:.0f} ms")
    print(f"decode:  {args.tokens-1} steps in {decode_s*1e3:.0f} ms ({tput:.0f} tok/s)")
    print(f"sample continuation (request 0): {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
