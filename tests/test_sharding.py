"""Sharding rules: divisibility fallback, cache specs, mesh construction."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import abstract_mesh, cache_spec, default_rules, spec_for
from repro.train.fault import largest_mesh_shape


@pytest.fixture(scope="module")
def mesh():
    # abstract 16x16 mesh over 1 real device is fine for spec logic tests
    # (abstract_mesh absorbs the AbstractMesh API drift across JAX versions)
    return abstract_mesh(("data", "model"), (16, 16))


class TestSpecFor:
    def test_basic_2d(self, mesh):
        s = spec_for((2048, 8192), ("embed", "mlp"), mesh)
        assert s == P("data", "model")

    def test_nondivisible_axis_dropped(self, mesh):
        # kv_heads=1 can't shard over model=16
        s = spec_for((2048, 1, 128), ("embed", "kv_heads", None), mesh)
        assert s == P("data")

    def test_axis_used_once(self, mesh):
        # two logical axes both wanting "model": second gets dropped
        s = spec_for((4096, 4096), ("mlp", "rnn"), mesh)
        assert s == P("model")

    def test_layers_never_sharded(self, mesh):
        s = spec_for((24, 2048, 8192), ("layers", "embed", "mlp"), mesh)
        assert s == P(None, "data", "model")


class TestCacheSpec:
    def test_kv_heads_preferred(self, mesh):
        # gemma-7b decode: kv=16 divisible -> heads on model, batch on data
        s = cache_spec((128, 32768, 16, 256), "kv", mesh)
        assert s == P("data", None, "model", None)

    def test_split_kv_when_heads_dont_divide(self, mesh):
        # internlm2 kv=8: sequence takes the model axis (flash-decoding)
        s = cache_spec((128, 32768, 8, 128), "kv", mesh)
        assert s == P("data", "model", None, None)

    def test_long_context_batch1_shards_sequence_everywhere(self, mesh):
        s = cache_spec((1, 524288, 1, 256), "kv", mesh)
        assert s == P(None, ("data", "model"), None, None)

    def test_recurrent_state(self, mesh):
        s = cache_spec((128, 4096), "state", mesh)
        assert s == P("data", "model")


class TestMesh:
    def test_production_mesh_shapes(self):
        # can't build 256-device mesh on 1 CPU; validate the spec instead
        from repro.launch import mesh as mesh_mod
        import inspect
        src = inspect.getsource(mesh_mod.make_production_mesh)
        assert "(2, 16, 16)" in src and "(16, 16)" in src
        assert '("pod", "data", "model")' in src

    def test_elastic_shrink_keeps_model_axis(self):
        assert largest_mesh_shape(512, 16) == (32, 16)
        assert largest_mesh_shape(511, 16) == (511, 1)
        assert largest_mesh_shape(508, 16) == (127, 4)
