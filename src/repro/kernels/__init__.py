"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

- ``its_select``  — fused CTPS build + ITS + BRS retry (SELECT, DESIGN.md §2)
- ``walk_step``   — segment-DMA weighted walk transition (DESIGN.md §6)
- ``ops``         — jit'd wrappers owning RNG and shape plumbing
- ``ref``         — pure-jnp oracles consuming the same random budgets

Kernels run compiled through Mosaic on TPU and fall back to ``interpret=True``
elsewhere (``resolve_interpret``); the selection backend dispatcher in
``repro.core.backend`` decides when the engine uses them at all.
"""
from repro.kernels.its_select import its_select_pallas, resolve_interpret
from repro.kernels.walk_step import (
    pad_csr_for_kernel,
    walk_step_pallas,
    walk_step_window_pallas,
)

__all__ = [
    "its_select_pallas",
    "walk_step_pallas",
    "walk_step_window_pallas",
    "pad_csr_for_kernel",
    "resolve_interpret",
]
