"""Contiguous vertex-range graph partitioning (paper §V-A).

The paper partitions by assigning a contiguous, equal range of vertices and
*all their neighbor lists* to one partition, because sampling requires every
edge of a vertex to be present to compute transition probabilities, and
because range membership is decidable in O(1) (``vertex // range_size``),
which the workload-aware scheduler relies on.

Device residency uses a *compact local-id* layout (DESIGN.md §8): a resident
partition's ``indptr`` covers only its own O(V/P) vertex range plus one
phantom sink row, never the full vertex space — the full ``V+1`` indptr of
the earlier layout defeated the very memory budget §V exists for.  Queue
entries keep global vertex ids (as in the paper); the rebase offset
``vertex_lo`` translates at the partition boundary.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class PartitionMap:
    """Cached contiguous-range bounds + O(1) partition lookup (paper §V-A).

    ``range_size = ceil(V / P)`` and ``pid(v) = min(v // range_size, P - 1)``
    — the paper's arithmetic membership test, with the bounds computed once
    and cached (they used to be recomputed on every hot-path lookup).
    """

    num_vertices: int
    num_partitions: int
    range_size: int
    bounds: np.ndarray  # (P+1,) int64 vertex range boundaries

    @staticmethod
    @functools.lru_cache(maxsize=128)
    def create(num_vertices: int, num_partitions: int) -> "PartitionMap":
        rs = -(-num_vertices // num_partitions)  # ceil
        bounds = np.minimum(
            np.arange(num_partitions + 1, dtype=np.int64) * rs, num_vertices
        )
        bounds.setflags(write=False)  # the cache shares this array
        return PartitionMap(num_vertices, num_partitions, rs, bounds)

    def pid_of(self, vertex) -> np.ndarray:
        """O(1) host-side lookup (no searchsorted, no bound rebuild)."""
        v = np.asarray(vertex)
        return np.clip(v // self.range_size, 0, self.num_partitions - 1)

    def pid_of_device(self, vertex: jax.Array) -> jax.Array:
        """Same lookup as traced device arithmetic (drain-loop scatter path)."""
        return pid_of_device(vertex, self.range_size, self.num_partitions)


def pid_of_device(vertex: jax.Array, range_size: int, num_partitions: int) -> jax.Array:
    """The membership formula as traced device arithmetic — the ONE home of
    ``min(v // range_size, P - 1)`` for jitted callers (the §V drain loop's
    cross-partition scatter and :meth:`PartitionMap.pid_of_device`)."""
    return jnp.clip(vertex // range_size, 0, num_partitions - 1).astype(jnp.int32)


def partition_of(vertex, num_vertices: int, num_partitions: int):
    """O(1) partition lookup through the cached :class:`PartitionMap`."""
    return PartitionMap.create(num_vertices, num_partitions).pid_of(vertex)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DevicePartition:
    """Device-resident compact partition CSR (local ids + phantom sink).

    ``graph`` is a local-id CSR: row ``i`` holds vertex ``vertex_lo + i``,
    and one extra *phantom* row of degree 0 at local id ``num_local_vertices``
    absorbs every neighbor outside the partition, so degree lookups on
    arbitrary (localized) ids are O(V/P)-safe without the full-V indptr.
    ``graph.indices`` therefore hold LOCAL ids; ``indices_global`` holds the
    untranslated neighbor ids, aligned edge-for-edge, for emitting walk
    output and cross-partition queue pushes in global id space.
    """

    graph: CSRGraph
    indices_global: jax.Array  # (E_P,) int32 global neighbor ids
    vertex_lo: jax.Array  # () int32 rebase offset
    vertex_hi: jax.Array  # () int32

    def tree_flatten(self):
        return (self.graph, self.indices_global, self.vertex_lo, self.vertex_hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_local_vertices(self) -> int:
        """Rows excluding the phantom sink (includes shape-padding rows)."""
        return self.graph.num_vertices - 1

    def localize(self, x: jax.Array) -> jax.Array:
        """Global vertex ids -> this partition's row-lookup ids.

        Ids outside the resident range (including -1 padding) map to the
        degree-0 phantom sink row, so any localized id is safe for
        degree/row lookups on ``graph``.  The single home of the phantom
        convention — the §V drain and the shared edge-context builder both
        route through here.
        """
        nloc = self.num_local_vertices
        inside = (x >= self.vertex_lo) & (x < self.vertex_lo + nloc)
        return jnp.where(inside, x - self.vertex_lo, nloc)


@dataclasses.dataclass
class RangePartition:
    """One partition: vertices [vertex_lo, vertex_hi) with their full rows."""

    pid: int
    vertex_lo: int
    vertex_hi: int
    # Local CSR over the owned vertex range. indptr is re-based to 0; indices
    # remain *global* vertex ids (edges may point to any partition).
    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray
    # Global edge offset of this partition's first edge in the source CSR —
    # lets device layouts preserve global block alignment (``edge_align``).
    edge_lo: int = 0

    @property
    def num_vertices(self) -> int:
        return self.vertex_hi - self.vertex_lo

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def to_local_device_csr(
        self,
        pad_vertices: Optional[int] = None,
        pad_edges: Optional[int] = None,
        edge_align: int = 0,
    ) -> DevicePartition:
        """Materialize the compact O(V/P + E_P) device CSR.

        ``pad_vertices`` / ``pad_edges`` round the arrays up to a common
        shape so every partition of a graph shares ONE jit trace of the
        drain loop; padding rows have degree 0 and padding edges weight 0,
        both unreachable through masked semantics.  The one device_put of
        the host staging arrays is the DMA (async on real accelerators —
        the TransferEngine's double buffering hinges on it).

        ``edge_align`` > 0 prepends ``edge_lo % edge_align`` inert edges so
        every row keeps its *global* block offset (``start % edge_align``
        unchanged by the rebase).  The degree-bucketed pick kernels cumsum
        over block-aligned windows whose float association is fixed by the
        within-block position, so preserving the offset is what makes a
        partition-local pick bit-identical to the full-graph pick
        (DESIGN.md §12); the mesh-sharded walk passes the largest bucket
        segment (every smaller segment divides it).
        """
        nv = self.num_vertices
        lead = (self.edge_lo % edge_align) if edge_align > 0 else 0
        pv = max(pad_vertices or nv, nv)
        pe = max(pad_edges or (lead + self.num_edges), lead + self.num_edges)
        indptr = np.empty(pv + 2, dtype=np.int32)  # pv rows + phantom sink
        indptr[: nv + 1] = self.indptr + lead
        indptr[nv + 1 :] = self.indptr[-1] + lead
        u_loc = self.indices.astype(np.int64) - self.vertex_lo
        in_part = (u_loc >= 0) & (u_loc < nv)
        indices_local = np.where(in_part, u_loc, pv).astype(np.int32)
        epad = pe - self.num_edges - lead
        indices_local = np.pad(indices_local, (lead, epad), constant_values=pv)
        indices_global = np.pad(
            self.indices.astype(np.int32), (lead, epad), constant_values=-1
        )
        weights = np.pad(self.weights.astype(np.float32), (lead, epad))
        ip_d, il_d, ig_d, w_d = jax.device_put((indptr, indices_local, indices_global, weights))
        return DevicePartition(
            graph=CSRGraph(indptr=ip_d, indices=il_d, weights=w_d),
            indices_global=ig_d,
            vertex_lo=jnp.int32(self.vertex_lo),
            vertex_hi=jnp.int32(self.vertex_hi),
        )


def select_hubs(
    indptr: np.ndarray,
    hub_bytes: int,
    seg_big: int,
    min_degree: int = 2,
    bytes_per_edge: int = 28,
) -> np.ndarray:
    """Pick the top-degree *hub* rows that fit a per-device byte budget.

    C-SAW's transfer-bound argument (and ThunderRW's access analysis) says
    hub vertices absorb most transition traffic on power-law graphs, so
    replicating the hot few rows on every device converts most exchange hops
    into local hops.  Rows are taken greedily by descending degree (stable
    on ties, so the set is deterministic) until the cumulative replicated
    footprint exceeds ``hub_bytes``; each hub costs
    ``(degree + seg_big) * bytes_per_edge`` — the ``seg_big`` addend is the
    worst-case alignment lead :func:`hub_edge_layout` may insert, and
    ``bytes_per_edge`` covers every per-edge lane the drain replicates
    (local/global indices, weight, bias, ITS table, alias table, target
    degree: 7 × 4 bytes).  Degree-``< min_degree`` rows are never worth
    replicating (a degree-1 hop exchanges as cheaply as it resolves).

    Returns the hub vertex ids **sorted ascending** — the traced
    :func:`localize_hybrid` lookup binary-searches this array.
    """
    deg = np.diff(np.asarray(indptr)).astype(np.int64)
    if hub_bytes <= 0:
        return np.empty(0, dtype=np.int64)
    order = np.argsort(-deg, kind="stable")
    cost = np.cumsum((deg[order] + max(seg_big, 0)) * bytes_per_edge)
    take = int(np.searchsorted(cost, hub_bytes, side="right"))
    hubs = order[:take]
    hubs = hubs[deg[hubs] >= min_degree]
    return np.sort(hubs).astype(np.int64)


def hub_edge_layout(
    indptr: np.ndarray, hubs: np.ndarray, hub_region_lo: int, seg_big: int
) -> tuple:
    """Alignment-preserving placement of replicated hub rows' edges.

    Hub ``s``'s edges are copied into the device edge arrays at
    ``starts[s]``, chosen so ``starts[s] % seg_big == indptr[hubs[s]] %
    seg_big`` — the same global-block-offset invariant
    :meth:`RangePartition.to_local_device_csr` keeps for resident rows,
    which is what makes a replicated-row pick bit-identical to the
    full-graph pick (DESIGN.md §12).  Placement is sequential from
    ``hub_region_lo`` with at most ``seg_big - 1`` junk edges between
    consecutive hubs.  All inputs are device-independent, so every device
    computes the identical layout.  Returns ``(starts, end)`` with
    ``starts`` int64 ``(H,)`` and ``end`` the first unused edge slot.
    """
    hubs = np.asarray(hubs)
    starts = np.empty(hubs.shape[0], dtype=np.int64)
    cur = int(hub_region_lo)
    for s, h in enumerate(hubs):
        g = int(indptr[h])
        lead = (g - cur) % seg_big if seg_big > 0 else 0
        starts[s] = cur + lead
        cur = int(starts[s]) + int(indptr[h + 1] - indptr[h])
    return starts, cur


def hybrid_host_csr(
    part: RangePartition,
    pad_vertices: int,
    pad_edges: int,
    edge_align: int,
    hubs: np.ndarray,
    hub_starts: np.ndarray,
    indptr_full: np.ndarray,
    indices_full: np.ndarray,
    weights_full: np.ndarray,
) -> tuple:
    """Host staging arrays for the hub-replicated *hybrid* layout.

    Row space (``pv = pad_vertices`` rows of resident range, ``H`` hubs)::

        rows 0 .. pv-1        resident local rows (padding rows degree 0)
        row  pv               bridge junk row (never addressed)
        row  pv + 1 + 2s      hub s  (indptr -> hub_starts[s], degree of hub)
        row  pv + 2 + 2s      junk gap row between hub s and hub s+1
        row  pv + 2H          phantom sink (degree 0) — ``H == 0`` reduces
                              to the exact legacy compact layout

    so ``indptr`` has ``pv + 2H + 2`` entries and any id produced by
    :func:`localize_hybrid` is safe for degree/row lookups.  Junk rows hold
    whatever offsets fall between placed regions; they are unreachable
    because :func:`localize_hybrid` never returns them.  Edge arrays are
    the legacy local region (global block alignment preserved via
    ``edge_align``) followed by the replicated hub region at
    ``hub_starts`` (from :func:`hub_edge_layout`); gaps carry local-index
    ``phantom``, global-index ``-1`` and weight ``0``.

    Returns ``(indptr, indices_local, indices_global, weights)`` as numpy
    arrays, ready for per-edge lane placement + one ``device_put``.
    """
    nv = part.num_vertices
    lead = (part.edge_lo % edge_align) if edge_align > 0 else 0
    pv = max(pad_vertices, nv)
    num_hubs = int(np.asarray(hubs).shape[0])
    phantom = pv + 2 * num_hubs
    end_local = lead + part.num_edges
    pe = max(pad_edges, end_local)
    if num_hubs:
        pe = max(pe, int(hub_starts[-1]) + int(np.diff(indptr_full)[hubs[-1]]))

    indptr = np.empty(phantom + 2, dtype=np.int32)
    indptr[: nv + 1] = part.indptr + lead
    indptr[nv + 1 : pv + 1] = end_local
    cur = end_local
    for s in range(num_hubs):
        h = int(hubs[s])
        indptr[pv + 1 + 2 * s] = int(hub_starts[s])
        cur = int(hub_starts[s]) + int(indptr_full[h + 1] - indptr_full[h])
        indptr[pv + 2 + 2 * s] = cur
    indptr[phantom] = cur
    indptr[phantom + 1] = cur

    indices_local = np.full(pe, phantom, dtype=np.int32)
    indices_global = np.full(pe, -1, dtype=np.int32)
    weights = np.zeros(pe, dtype=np.float32)
    u_loc = part.indices.astype(np.int64) - part.vertex_lo
    in_part = (u_loc >= 0) & (u_loc < nv)
    indices_local[lead:end_local] = np.where(in_part, u_loc, phantom).astype(np.int32)
    indices_global[lead:end_local] = part.indices.astype(np.int32)
    weights[lead:end_local] = part.weights.astype(np.float32)
    for s in range(num_hubs):
        h = int(hubs[s])
        g0, g1 = int(indptr_full[h]), int(indptr_full[h + 1])
        d0 = int(hub_starts[s])
        hub_u = indices_full[g0:g1].astype(np.int64)
        hu_loc = hub_u - part.vertex_lo
        h_in = (hu_loc >= 0) & (hu_loc < nv)
        indices_local[d0 : d0 + g1 - g0] = np.where(h_in, hu_loc, phantom).astype(
            np.int32
        )
        indices_global[d0 : d0 + g1 - g0] = indices_full[g0:g1].astype(np.int32)
        weights[d0 : d0 + g1 - g0] = weights_full[g0:g1].astype(np.float32)
    return indptr, indices_local, indices_global, weights


def place_hub_edges(
    base: np.ndarray,
    full: np.ndarray,
    indptr_full: np.ndarray,
    hubs: np.ndarray,
    hub_starts: np.ndarray,
) -> np.ndarray:
    """Copy a full-graph per-edge lane into the hybrid layout's hub region.

    ``base`` already holds the lane's local region (and gap fill); each hub
    row's slice of ``full`` lands at its :func:`hub_edge_layout` offset.
    Used for the bias / ITS / alias / target-degree lanes, which the drain
    must read identically whether a row is resident or replicated.
    """
    out = np.asarray(base).copy()
    for s in range(int(np.asarray(hubs).shape[0])):
        h = int(hubs[s])
        g0, g1 = int(indptr_full[h]), int(indptr_full[h + 1])
        d0 = int(hub_starts[s])
        out[d0 : d0 + g1 - g0] = full[g0:g1]
    return out


def localize_hybrid(
    x: jax.Array,
    vertex_lo,
    num_rows: int,
    hubs: jax.Array,
    num_hubs: int,
) -> jax.Array:
    """Global vertex ids -> hybrid row ids (resident, hub, or phantom).

    The hub-aware extension of :meth:`DevicePartition.localize`: ids in the
    resident range rebase to rows ``0..num_rows-1`` (the resident copy wins
    when a hub also happens to be resident — both copies are pick-identical
    by the alignment invariant); ids matching a replicated hub (binary
    search over the sorted ``hubs``) map to row ``num_rows + 1 + 2*pos``;
    everything else (including ``-1`` padding) maps to the degree-0
    phantom sink at ``num_rows + 2*num_hubs``.  ``locrow != phantom`` is
    the drain's stay-local test: hub-destined walkers never enter the
    exchange — the locality win the hybrid partition exists for.
    """
    phantom = num_rows + 2 * num_hubs
    inside = (x >= vertex_lo) & (x < vertex_lo + num_rows)
    loc = jnp.where(inside, x - vertex_lo, phantom).astype(jnp.int32)
    if num_hubs:
        pos = jnp.searchsorted(hubs, x)
        posc = jnp.clip(pos, 0, num_hubs - 1)
        is_hub = (pos < num_hubs) & (hubs[posc] == x)
        hub_row = (num_rows + 1 + 2 * posc).astype(jnp.int32)
        loc = jnp.where(inside, loc, jnp.where(is_hub, hub_row, phantom)).astype(
            jnp.int32
        )
    return loc


def partition_by_vertex_range(graph: CSRGraph, num_partitions: int) -> List[RangePartition]:
    """Split a CSRGraph into ``num_partitions`` contiguous vertex ranges."""
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    weights = np.asarray(graph.weights)
    n = indptr.shape[0] - 1
    bounds = PartitionMap.create(n, num_partitions).bounds
    parts: List[RangePartition] = []
    for pid in range(num_partitions):
        lo, hi = int(bounds[pid]), int(bounds[pid + 1])
        e_lo, e_hi = int(indptr[lo]), int(indptr[hi])
        local_indptr = (indptr[lo : hi + 1] - indptr[lo]).astype(np.int32)
        parts.append(
            RangePartition(
                pid=pid,
                vertex_lo=lo,
                vertex_hi=hi,
                indptr=local_indptr,
                indices=indices[e_lo:e_hi].copy(),
                weights=weights[e_lo:e_hi].copy(),
                edge_lo=e_lo,
            )
        )
    return parts
