"""Model zoo: composable decoder LM supporting all assigned architectures."""
from repro.models.model import (
    abstract_cache,
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    model_defs,
    param_logical_axes,
)

__all__ = [
    "abstract_cache",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "model_defs",
    "param_logical_axes",
]
