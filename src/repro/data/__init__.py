"""data subpackage."""
