"""Out-of-memory partition scheduler (paper §V)."""
import jax
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.oom import oom_random_walk
from repro.graph import powerlaw_graph
from repro.graph.partition import PartitionMap, partition_by_vertex_range, partition_of


@pytest.fixture(scope="module")
def setup():
    g = powerlaw_graph(512, seed=3, weighted=True)
    parts = partition_by_vertex_range(g, 4)
    seeds = np.random.default_rng(0).integers(0, 512, 96)
    return g, parts, seeds


class TestPartitioning:
    def test_ranges_cover_all_vertices(self, setup):
        g, parts, _ = setup
        assert parts[0].vertex_lo == 0
        assert parts[-1].vertex_hi == g.num_vertices
        for a, b in zip(parts[:-1], parts[1:]):
            assert a.vertex_hi == b.vertex_lo

    def test_all_edges_of_vertex_in_one_partition(self, setup):
        """The paper's core partitioning requirement (§V-A)."""
        g, parts, _ = setup
        ip = np.asarray(g.indptr)
        for p in parts:
            expect = ip[p.vertex_hi] - ip[p.vertex_lo]
            assert p.num_edges == expect

    def test_partition_of_constant_time_lookup(self, setup):
        g, parts, _ = setup
        v = np.arange(g.num_vertices)
        pid = partition_of(v, g.num_vertices, 4)
        for p in parts:
            assert (pid[p.vertex_lo : p.vertex_hi] == p.pid).all()

    def test_partition_map_caches_bounds(self):
        """The O(1) arithmetic lookup runs off cached bounds — same object
        back for the same (V, P), no per-call bound rebuild."""
        a = PartitionMap.create(1000, 8)
        b = PartitionMap.create(1000, 8)
        assert a is b
        assert a.range_size == 125
        np.testing.assert_array_equal(a.pid_of([0, 124, 125, 999]), [0, 0, 1, 7])
        np.testing.assert_array_equal(
            np.asarray(a.pid_of_device(np.array([0, 124, 125, 999]))), [0, 0, 1, 7]
        )

    def test_local_device_csr_matches_global(self, setup):
        """Row contents survive the compact local-id materialization; global
        neighbor ids come back through ``indices_global``."""
        g, parts, _ = setup
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        part = parts[1]
        dev = part.to_local_device_csr()
        dip = np.asarray(dev.graph.indptr)
        dig = np.asarray(dev.indices_global)
        for v in range(part.vertex_lo, part.vertex_hi):
            lv = v - part.vertex_lo
            np.testing.assert_array_equal(
                dig[dip[lv] : dip[lv + 1]], ind[ip[v] : ip[v + 1]]
            )

    def test_resident_indptr_is_local_size(self, setup):
        """§V memory budget: the resident CSR is O(V/P + E_P), NOT O(V).
        The old layout shipped a full (total_vertices + 1) indptr per
        resident partition, which defeated out-of-memory support."""
        g, parts, _ = setup
        part = parts[1]
        dev = part.to_local_device_csr()
        assert dev.graph.indptr.shape[0] == part.num_vertices + 2  # rows + phantom
        assert dev.graph.indptr.shape[0] < g.num_vertices
        assert dev.indices_global.shape[0] == part.num_edges

    def test_cross_partition_neighbors_localize_to_phantom(self, setup):
        """Neighbors outside the partition map to the degree-0 phantom sink,
        so local degree lookups are safe for arbitrary localized ids."""
        g, parts, _ = setup
        part = parts[1]
        dev = part.to_local_device_csr()
        il = np.asarray(dev.graph.indices)
        ig = np.asarray(dev.indices_global)
        phantom = dev.graph.num_vertices - 1
        outside = (ig < part.vertex_lo) | (ig >= part.vertex_hi)
        assert outside.any()  # the fixture graph does have cross edges
        assert (il[outside] == phantom).all()
        inside = ~outside
        np.testing.assert_array_equal(il[inside], ig[inside] - part.vertex_lo)
        dip = np.asarray(dev.graph.indptr)
        assert dip[phantom] == dip[phantom + 1]  # phantom row is empty


class TestOOMWalk:
    def test_walks_valid(self, setup):
        g, parts, seeds = setup
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        walks, stats = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=8,
            spec=alg.biased_random_walk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128)
        assert walks.shape == (96, 9)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert b in ind[ip[a] : ip[a + 1]]
        assert stats.sampled_edges > 0
        assert stats.partition_transfers >= 2
        assert stats.frontier_dropped == 0

    def test_seeds_survive_padding_writes(self, setup):
        """Regression: the walks scatter's drop sentinel must be OOB-positive
        — JAX wraps negative scatter indices even under mode="drop", so a -1
        sentinel for padding/dead-end entries silently overwrites the LAST
        instance's row (invisible to the backend-parity tests, which corrupt
        identically on both sides)."""
        g, parts, seeds = setup
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(3), depth=8,
            spec=alg.weighted_random_walk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128)
        np.testing.assert_array_equal(walks[:, 0], seeds)

    def test_batching_reduces_kernel_launches(self, setup):
        """Paper Fig. 13: batched multi-instance vs per-instance."""
        g, parts, seeds = setup
        _, s_batched = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=128)
        _, s_single = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=128,
            batched=False)
        assert s_batched.kernel_launches < s_single.kernel_launches / 2

    def test_workload_aware_not_more_transfers(self, setup):
        """Paper Fig. 15: workload-aware scheduling cuts transfers."""
        g, parts8 = setup[0], partition_by_vertex_range(setup[0], 8)
        seeds = setup[2]
        _, s_ws = oom_random_walk(
            parts8, g.num_vertices, seeds, jax.random.PRNGKey(1), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128, workload_aware=True)
        _, s_rr = oom_random_walk(
            parts8, g.num_vertices, seeds, jax.random.PRNGKey(1), depth=6,
            spec=alg.deepwalk(), max_degree=g.max_degree(),
            memory_capacity=2, chunk=128, workload_aware=False, balance=False)
        assert s_ws.partition_transfers <= s_rr.partition_transfers

    def test_results_independent_of_scheduling(self, setup):
        """Correctness argument from the paper (§V-B): out-of-order partition
        scheduling must not change which seeds produce walks (same seeds,
        same depth coverage)."""
        g, parts, seeds = setup
        w1, _ = oom_random_walk(parts, g.num_vertices, seeds, jax.random.PRNGKey(2),
                                depth=5, spec=alg.deepwalk(), max_degree=g.max_degree(),
                                workload_aware=True, chunk=64)
        w2, _ = oom_random_walk(parts, g.num_vertices, seeds, jax.random.PRNGKey(2),
                                depth=5, spec=alg.deepwalk(), max_degree=g.max_degree(),
                                workload_aware=False, chunk=64)
        np.testing.assert_array_equal(w1[:, 0], w2[:, 0])
        # same number of completed steps per instance (dead ends aside, all
        # should reach full depth on this connected-ish graph)
        assert (w1 >= 0).sum() > 0.9 * w1.size
        assert (w2 >= 0).sum() > 0.9 * w2.size


class TestBackendParity:
    """`backend="pallas"` (interpret mode off-TPU) must reproduce the
    reference backend bit-for-bit — walks AND stats (DESIGN.md §4/§8)."""

    def _stats_tuple(self, s):
        return (
            s.partition_transfers, s.bytes_transferred, s.kernel_launches,
            tuple(s.entries_per_kernel), s.sampled_edges, s.frontier_dropped,
        )

    def test_flat_fast_path_bitwise(self, setup):
        """Weighted walk takes the degree-bucketed flat_edge_bias fast path
        on both backends (kernel vs pure-jnp mirror, same RNG bits)."""
        g, parts, seeds = setup
        kw = dict(depth=8, spec=alg.weighted_random_walk(),
                  max_degree=g.max_degree(), memory_capacity=2, chunk=128)
        w_ref, s_ref = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(5), backend="reference", **kw)
        w_pal, s_pal = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(5), backend="pallas", **kw)
        np.testing.assert_array_equal(w_ref, w_pal)
        assert self._stats_tuple(s_ref) == self._stats_tuple(s_pal)

    def test_window_path_bitwise(self, setup):
        """node2vec (prev-dependent bias) runs the bucketed WINDOW path in
        the drain loop: the dynamic hook evaluates on gathered edge windows
        in shared jnp, the pick dispatches kernel vs mirror — walks and
        stats bit-identical."""
        g, parts, seeds = setup
        kw = dict(depth=4, spec=alg.node2vec(), max_degree=g.max_degree(),
                  memory_capacity=2, chunk=64)
        w_ref, s_ref = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(6), backend="reference", **kw)
        w_pal, s_pal = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(6), backend="pallas", **kw)
        np.testing.assert_array_equal(w_ref, w_pal)
        assert self._stats_tuple(s_ref) == self._stats_tuple(s_pal)

    def test_gather_fallback_bitwise(self, setup):
        """A genuinely opaque spec (no transition program, no flat bias)
        keeps the dense gather step; the ITS draw still dispatches through
        the backend and stays bit-identical."""
        import dataclasses

        g, parts, seeds = setup
        spec = dataclasses.replace(
            alg.node2vec(), transition=None, flat_edge_bias=None)
        kw = dict(depth=3, spec=spec, max_degree=g.max_degree(),
                  memory_capacity=2, chunk=64)
        w_ref, s_ref = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(6), backend="reference", **kw)
        w_pal, s_pal = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(6), backend="pallas", **kw)
        np.testing.assert_array_equal(w_ref, w_pal)
        assert self._stats_tuple(s_ref) == self._stats_tuple(s_pal)

    def test_understated_max_degree_still_walks_hubs(self):
        """Regression: the flat fast path plans its degree buckets from the
        TRUE max row degree, so a caller-understated ``max_degree`` must not
        silently kill walkers at hubs (the gather path truncates instead) —
        and the two backends must stay bit-identical there."""
        from repro.graph import csr_from_edges

        hub_deg = 700
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        w = np.random.default_rng(0).uniform(0.1, 2.0, src.shape[0]).astype(np.float32)
        g = csr_from_edges(hub_deg + 1, src, dst, w)
        parts = partition_by_vertex_range(g, 4)
        seeds = np.zeros(16, np.int64)  # all start at the hub
        kw = dict(depth=4, spec=alg.weighted_random_walk(), max_degree=256,
                  memory_capacity=2, chunk=64)
        w_ref, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(4), backend="reference", **kw)
        w_pal, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(4), backend="pallas", **kw)
        assert (w_ref[:, 1] >= 1).all()  # hub walkers stepped, not killed
        np.testing.assert_array_equal(w_ref, w_pal)

    def test_understated_max_degree_window_path(self):
        """Window-bias programs plan buckets from the TRUE max row degree
        like the flat path: a deg-700 hub with declared max_degree=256 must
        still walk (chunked dynamic tail), bit-identically across backends."""
        from repro.graph import csr_from_edges

        hub_deg = 700
        src = np.concatenate([np.zeros(hub_deg, int), np.arange(1, hub_deg + 1)])
        dst = np.concatenate([np.arange(1, hub_deg + 1), np.zeros(hub_deg, int)])
        w = np.random.default_rng(0).uniform(0.1, 2.0, src.shape[0]).astype(np.float32)
        g = csr_from_edges(hub_deg + 1, src, dst, w)
        parts = partition_by_vertex_range(g, 4)
        seeds = np.zeros(16, np.int64)  # all start at the hub
        kw = dict(depth=4, spec=alg.node2vec(), max_degree=256,
                  memory_capacity=2, chunk=64)
        w_ref, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(4), backend="reference", **kw)
        w_pal, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(4), backend="pallas", **kw)
        assert (w_ref[:, 1] >= 1).all()  # hub walkers stepped, not killed
        np.testing.assert_array_equal(w_ref, w_pal)

    def test_flat_matches_in_memory_stationary(self, setup):
        """The OOM deepwalk visits ∝ degree like the in-memory engine — the
        device frontier refactor must not distort the walk distribution."""
        g, parts, _ = setup
        seeds = np.random.default_rng(1).integers(0, g.num_vertices, 512)
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(8), depth=20,
            spec=alg.deepwalk(), max_degree=g.max_degree(), chunk=256)
        last = walks[:, -1]
        last = last[last >= 0]
        deg = np.asarray(g.indptr[1:] - g.indptr[:-1]).astype(float)
        visit = np.bincount(last, minlength=g.num_vertices).astype(float)
        assert np.corrcoef(visit, deg)[0, 1] > 0.5


class TestNonFlatSpecsOOM:
    """Transition programs with epilogues and window biases complete
    out-of-memory (paper §V) — for the first time not just flat specs."""

    @pytest.fixture(scope="class")
    def setup(self):
        g = powerlaw_graph(512, seed=3, weighted=True)
        parts = partition_by_vertex_range(g, 4)
        seeds = np.random.default_rng(7).integers(0, 512, 96)
        return g, parts, seeds

    def _run(self, setup, spec, backend="reference", depth=6):
        g, parts, seeds = setup
        return oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(9), depth=depth,
            spec=spec, max_degree=g.max_degree(), memory_capacity=2,
            chunk=128, backend=backend)

    def test_node2vec_walks_are_paths(self, setup):
        g, _, seeds = setup
        walks, stats = self._run(setup, alg.node2vec())
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        np.testing.assert_array_equal(walks[:, 0], seeds)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert b in ind[ip[a] : ip[a + 1]]
        assert (walks >= 0).all()  # connected-ish graph: full depth
        assert stats.sampled_edges > 0

    def test_mhrw_stays_or_moves(self, setup):
        g, _, _ = setup
        walks, _ = self._run(setup, alg.metropolis_hastings_walk())
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert a == b or b in ind[ip[a] : ip[a + 1]]

    def test_jump_crosses_partitions(self, setup):
        g, _, _ = setup
        walks, _ = self._run(setup, alg.random_walk_with_jump(1.0, g.num_vertices))
        # all-jump walk: successors are uniform over V, not constrained to edges
        assert len(np.unique(walks[:, 1])) > 16
        assert (walks >= 0).all()

    def test_restart_home_returns_to_seed(self, setup):
        walks, _ = self._run(setup, alg.random_walk_with_restart(1.0))
        for row in walks:
            alive = row[1:][row[1:] >= 0]
            assert (alive == row[0]).all()

    @pytest.mark.parametrize("name", ["node2vec", "mhrw", "jump", "restart_home"])
    def test_backend_parity(self, setup, name):
        g = setup[0]
        spec = {
            "node2vec": alg.node2vec(),
            "mhrw": alg.metropolis_hastings_walk(),
            "jump": alg.random_walk_with_jump(0.3, g.num_vertices),
            "restart_home": alg.random_walk_with_restart(0.3),
        }[name]
        w_ref, _ = self._run(setup, spec, backend="reference", depth=4)
        w_pal, _ = self._run(setup, spec, backend="pallas", depth=4)
        np.testing.assert_array_equal(w_ref, w_pal)


class TestStrictOverflow:
    """Queue-capacity overflow must surface, never silently lose walkers."""

    def _run(self, setup, **kw):
        g, parts, seeds = setup
        return oom_random_walk(
            parts, g.num_vertices, seeds, jax.random.PRNGKey(0),
            depth=6, spec=alg.deepwalk(), max_degree=g.max_degree(),
            chunk=32, **kw,
        )

    def test_default_capacity_never_drops(self, setup):
        _, stats = self._run(setup, strict=True)  # strict must not trip
        assert stats.frontier_dropped == 0

    def test_tiny_capacity_counts_drops_in_stats(self, setup):
        # 96 instances funneled into 4 queues of 8 slots: guaranteed overflow
        walks, stats = self._run(setup, queue_capacity=8)
        assert stats.frontier_dropped > 0
        # dropped walkers freeze (short rows) instead of corrupting others
        assert (walks[:, 0] >= 0).all()

    def test_strict_mode_raises_with_clear_error(self, setup):
        with pytest.raises(RuntimeError, match="dropped .* capacity overflow"):
            self._run(setup, queue_capacity=8, strict=True)
