"""Batched serving demo: the multi-instance sampling service.

Spins up a :class:`repro.serve.SamplingService` over a power-law graph and
feeds it a burst of concurrent, heterogeneous requests — mixed algorithms
(deepwalk / weighted / node2vec), mixed walk lengths, mixed seed-set sizes —
then drains them through fused device launches and prints the per-request
results plus the batching stats (launches vs requests, padding overhead).

    PYTHONPATH=src python examples/serve_batch.py --requests 24

With ``--oom`` the service instead holds the graph as 8 host-resident
vertex-range partitions (2 resident at a time) and routes every cohort
through the §V frontier-queue drain (DESIGN.md §8) — same submit/drain API,
per-request ``depth_limits`` merged into one partition schedule.

    PYTHONPATH=src python examples/serve_batch.py --oom

With ``--sharded`` the graph is range-sharded over a device mesh (8 forced
host devices when no accelerators are present) and every cohort drains
through the owner-routed frontier exchange (``repro.shard``, DESIGN.md
§12) — per-device CSR footprint ∝ 1/D, walkers routed to the shard owning
their frontier vertex each step:

    PYTHONPATH=src python examples/serve_batch.py --sharded

With ``--stream`` the service runs always-on instead of submit-then-drain:
a :class:`repro.serve.StreamingSamplingService` scheduler thread forms
cohorts continuously while an open-loop Poisson load generator submits
mixed-spec requests across three priority tiers (interactive requests
carry 50 ms deadlines, bulk 500 ms, standard ride the batching window).
Prints per-tier p50/p99 latency, sustained requests/s, and the launch
triggers that fired (DESIGN.md §15):

    PYTHONPATH=src python examples/serve_batch.py --stream --rate 80

``--lm`` keeps the original language-model serving demo (prefill + decode
with the KV/state cache on a smoke-scale arch):

    PYTHONPATH=src python examples/serve_batch.py --lm --arch gemma3-1b
"""
import argparse
import os
import sys
import time

# the sharded scenario needs a device mesh: force host devices BEFORE jax
# initializes (a no-op when the platform already has real accelerators)
if "--sharded" in sys.argv and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np


def run_sampling_service(args) -> None:
    """Submit a burst of mixed requests, drain, report batching wins."""
    from repro.core import algorithms as alg
    from repro.graph import powerlaw_graph
    from repro.graph.partition import partition_by_vertex_range
    from repro.serve import SamplingService, ServiceConfig

    g = powerlaw_graph(20_000, exponent=2.1, seed=0, weighted=True)
    print(f"graph: V={g.num_vertices} E={g.num_edges} maxdeg={g.max_degree()}")

    if args.oom:
        parts = partition_by_vertex_range(g, 8)
        svc = SamplingService(
            partitions=parts, total_vertices=g.num_vertices,
            backend=args.backend, oom_memory_capacity=2, oom_chunk=256,
        )
        print(f"mode: out-of-memory ({len(parts)} partitions, 2 resident)")
    elif args.sharded:
        ndev = jax.device_count()
        mesh = jax.make_mesh((ndev,), ("data",))
        svc = SamplingService(
            g, mesh=mesh, placement="sharded", backend=args.backend,
        )
        print(f"mode: mesh-sharded ({ndev} devices, per-device CSR ~1/{ndev})")
    else:
        svc = SamplingService(g, backend=args.backend, config=ServiceConfig())
        print("mode: in-memory fused launches")

    # a burst of heterogeneous requests, as independent users would send them
    rng = np.random.default_rng(3)
    specs = [alg.deepwalk(), alg.weighted_random_walk(), alg.node2vec()]
    tickets = {}
    for i in range(args.requests):
        spec = specs[i % len(specs)]
        n = int(rng.integers(16, 129))
        depth = int(rng.choice([8, 12, 16, 24, 32]))
        seeds = rng.integers(0, g.num_vertices, n)
        rid = svc.submit(seeds, depth=depth, spec=spec)
        tickets[rid] = (spec.name, n, depth)

    t0 = time.perf_counter()
    results = svc.drain()
    secs = time.perf_counter() - t0

    for rid in sorted(results)[:6]:
        name, n, depth = tickets[rid]
        r = results[rid]
        print(f"  req {rid:2d} {name:12s} {n:4d} walkers x depth {depth:3d} "
              f"-> mean len {r.lengths.mean():5.1f}, {r.sampled_edges} edges")
    if len(results) > 6:
        print(f"  ... {len(results) - 6} more requests")
    s = svc.stats
    launches = (
        s.oom_launches if args.oom
        else s.sharded_launches if args.sharded
        else s.launches
    )
    print(f"served {s.requests_served} requests / {s.walkers_served} walkers "
          f"in {launches} launches ({secs*1e3:.0f} ms)")
    print(f"padding overhead: {s.padded_walker_slots} ghost walker slots")


def run_streaming_demo(args) -> None:
    """Open-loop streaming demo: Poisson arrivals against the always-on
    scheduler, mixed specs and priority tiers, per-tier latency report."""
    import collections

    from repro.core import algorithms as alg
    from repro.graph import powerlaw_graph
    from repro.serve import (
        Priority,
        SamplingService,
        ServiceConfig,
        StreamConfig,
        StreamingSamplingService,
    )
    from repro.serve.stream import percentile

    g = powerlaw_graph(20_000, exponent=2.1, seed=0, weighted=True)
    print(f"graph: V={g.num_vertices} E={g.num_edges} maxdeg={g.max_degree()}")

    depth, width, max_cohort = 8, 16, 16
    svc = SamplingService(
        g, backend=args.backend, config=ServiceConfig(
            max_pending_requests=1 << 14, max_pending_walkers=1 << 20,
            max_requests_per_launch=max_cohort,
        ),
    )
    specs = [alg.deepwalk(), alg.weighted_random_walk()]
    print("prewarming launch traces (so no live request pays the compile)...")
    for spec in specs:
        r = 1
        while r <= max_cohort:
            svc.prewarm(spec, depth=depth, width=width, requests=r)
            r *= 2

    tiers = {
        Priority.INTERACTIVE: ("interactive", 50.0),
        Priority.STANDARD: ("standard", None),
        Priority.BULK: ("bulk", 500.0),
    }
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    print(f"mode: always-on streaming — {args.requests} Poisson arrivals at "
          f"{args.rate:.0f} req/s, 10 ms batching window")

    futs = []
    with StreamingSamplingService(
        svc, StreamConfig(max_batch_window_ms=10.0)
    ) as stream:
        t0 = time.perf_counter()
        for i, at in enumerate(arrivals):
            delay = t0 + at - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            tier = [Priority.INTERACTIVE, Priority.STANDARD, Priority.BULK,
                    Priority.STANDARD][i % 4]
            futs.append(stream.submit(
                rng.integers(0, g.num_vertices, int(rng.integers(9, width + 1))),
                depth=depth, spec=specs[i % 2],
                deadline_ms=tiers[tier][1], priority=tier,
            ))
        for f in futs:
            f.result(timeout=600)
        elapsed = time.perf_counter() - t0

    lats = [f.latency for f in futs]
    print(f"\nserved {len(futs)} requests in {elapsed:.2f}s "
          f"({len(futs) / elapsed:.0f} req/s sustained), "
          f"{svc.stats.stream_launches} launches, "
          f"{svc.stats.stream_deadline_misses} deadline misses")
    reasons = collections.Counter(l.reason for l in lats)
    print("launch triggers: " + ", ".join(f"{k}={v}" for k, v in reasons.most_common()))
    print(f"{'tier':>12s} {'n':>4s} {'p50 ms':>8s} {'p99 ms':>8s}")
    for tier, (name, deadline) in tiers.items():
        tl = [l.total_ms for l in lats if l.tier == int(tier)]
        if tl:
            print(f"{name:>12s} {len(tl):4d} {percentile(tl, 50):8.1f} "
                  f"{percentile(tl, 99):8.1f}"
                  + (f"   (deadline {deadline:.0f} ms)" if deadline else ""))


def run_lm_demo(args) -> None:
    """Original LM serving demo: prefill + decode with the KV/state cache."""
    from repro.configs import get_smoke_config
    from repro.models import decode_step, init_cache, init_params  # noqa: F401
    from repro.train.train_step import make_serve_step

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    max_len = args.prompt_len + args.tokens
    serve, _ = make_serve_step(cfg, mesh, batch=args.batch, max_len=max_len)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = init_cache(cfg, args.batch, max_len)

    # prefill: feed prompt tokens through the decode path (recurrent archs
    # have O(1) state; attention archs fill the KV cache)
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = serve(params, cache, prompts[:, t : t + 1])
    prefill_s = time.perf_counter() - t0

    # decode: greedy continuation
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0
    seqs = np.concatenate(out, axis=1)
    tput = args.batch * (args.tokens - 1) / decode_s
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s*1e3:.0f} ms")
    print(f"decode:  {args.tokens-1} steps in {decode_s*1e3:.0f} ms ({tput:.0f} tok/s)")
    print(f"sample continuation (request 0): {seqs[0][:16].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24,
                    help="number of concurrent sampling requests to submit")
    ap.add_argument("--backend", default="auto",
                    help="selection backend: auto/reference/pallas")
    ap.add_argument("--oom", action="store_true",
                    help="serve through the out-of-memory partition scheduler")
    ap.add_argument("--sharded", action="store_true",
                    help="serve over a device mesh via the owner-routed "
                         "frontier exchange (forces 8 host devices on CPU)")
    ap.add_argument("--stream", action="store_true",
                    help="run the always-on streaming demo: open-loop "
                         "Poisson arrivals, priority tiers, per-tier p50/p99")
    ap.add_argument("--rate", type=float, default=80.0,
                    help="streaming demo offered load, requests/s")
    ap.add_argument("--lm", action="store_true",
                    help="run the language-model serving demo instead")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    if args.lm:
        run_lm_demo(args)
    elif args.stream:
        run_streaming_demo(args)
    else:
        run_sampling_service(args)


if __name__ == "__main__":
    main()
