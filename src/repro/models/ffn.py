"""Dense feed-forward blocks (GeGLU / SwiGLU / plain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ACTIVATIONS, ParamDef, ashard, rp_einsum


def ffn_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    defs = {
        "wi": ParamDef((d, f), ("embed", "mlp")),
        "wo": ParamDef((f, d), ("mlp", "embed")),
    }
    if cfg.glu:
        defs["wg"] = ParamDef((d, f), ("embed", "mlp"))
    return defs


def ffn_apply(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = ACTIVATIONS[cfg.activation]
    h = ashard(jnp.einsum("bsd,df->bsf", x, params["wi"]), "batch", None, "model")
    if cfg.glu:
        g = ashard(jnp.einsum("bsd,df->bsf", x, params["wg"]), "batch", None, "model")
        h = act(g) * h
    else:
        h = act(h)
    return rp_einsum("bsf,fd->bsd", h, params["wo"], cfg.reduce_dtype)
