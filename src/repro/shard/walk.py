"""Owner-routed sharded random walk over a device mesh (paper §V-D, scaled).

Each device of the mesh holds ONE contiguous vertex-range partition as a
compact local-id CSR (HBM ∝ 1/D, ``graph.partition.DevicePartition``) — plus
a small replicated *hub* region (below) — and a device-resident frontier
queue of the walkers currently AT its vertices (``shard.exchange.ShardQueue``).
A drain round:

1. flushes the deferred emigrants: per-destination cumsum compaction into
   fixed ``(D, slots)`` buffers, ONE tiled ``all_to_all``, per-destination
   overflow *deferred* to the next round (never dropped), received walkers
   pushed into the local queue,
2. runs ``sub_rounds`` local sub-rounds, each popping the queue (every
   popped walker's vertex is resident or hub-replicated, so its full
   neighbor row is local), taking one walk step through the SAME
   degree-bucketed selection dispatch the single-device engines use
   (``core.backend``; both backends), and pushing survivors back into the
   local queue (resident- or hub-destined) or the deferred buffer
   (cold-row emigrants),
3. a fused ``psum`` of live/deferred counts decides termination and skips
   empty exchanges.

**Hub replication (C-SAW's transfer-bound argument, DESIGN.md §14).**  On
power-law graphs a few hub rows absorb most transition traffic, so the
top-degree rows — budgeted in bytes, ``graph.partition.select_hubs`` — are
replicated on EVERY device alongside the compact range shard
(``hybrid_host_csr``).  A hop into a hub resolves locally on whatever device
the walker already occupies and never enters the exchange; only cold-row
hops pay the collective.  Hub edges keep their global block alignment
(``hub_edge_layout``), so a pick off a replicated row is bit-identical to
the owner's pick.  Interleaving the exchange of round N's emigrants with
round N+1's local sub-rounds (step 1 vs 2 above) amortizes one collective
over ``sub_rounds`` local steps.

The whole drain is one ``lax.scan`` inside one ``shard_map`` inside one
``jit`` per (shard shape, spec, backend) — meshes of the same shape reuse
the trace; a host loop re-invokes the compiled block only while walkers
remain (deferred-overflow slack).

**Bit-identical parity.**  ``sharded_random_walk`` reproduces single-device
``engine.random_walk`` exactly, bit for bit, on both backends, for EVERY
non-opaque transition program — flat and window biases (including
``needs_deg_u``), identity / teleport / MH-accept epilogues — because every
source of divergence is pinned (DESIGN.md §12, §14):

- *RNG*: the engine draws each step's uniforms as position-indexed ``(W,)``
  vectors under ``fold_in(key, depth)`` chains.  The sharded drain derives
  the SAME counted stream per entry — keyed by the walker's own (depth,
  instance), not by its slot on whatever device it landed on — via
  ``draw(key_of(depth))[instance]``.  Counted RNG is also what makes the
  sub-round restructure safe: a draw depends on (depth, instance), never on
  WHEN or WHERE the entry was popped.
- *Selection arithmetic*: the pick kernels cumsum block-aligned CSR windows
  whose float association is fixed by within-window position, so partitions
  are materialized with ``edge_align = max(buckets)`` lead padding —
  every row (resident AND hub-replicated) keeps its global ``start % seg``
  offset and the partition-local cumsum reproduces the full-graph bits.
- *Flat biases*: evaluated ONCE on the full graph at partition time and
  sliced per shard (a neighbor-degree bias needs non-resident degrees, which
  a shard cannot see), so per-edge bias bits match by construction.
- *Prev-dependent window biases* (node2vec): the previous vertex's neighbor
  row is CARRIED with the walker through the exchange (gathered at the
  source shard, which owns it), so ``is_prev_neighbor`` is exact without
  any replicated adjacency.
- *Non-resident degrees* (``needs_deg_u`` window biases, MH-accept): a
  replicated per-edge *target-degree lane* ``deg_tgt[e] = deg(indices[e])``
  — sliced/placed exactly like the flat bias — resolves ``deg(u)`` for any
  candidate at the source shard, no degree ever crosses the wire.
  MH-accept locates the selected neighbor's edge by binary search in the
  current row (rows are destination-sorted, ``csr_from_edges``) and decides
  acceptance through the engine's own ``transition.mh_stay``.

Only programs with OPAQUE hooks (``OpaqueBias`` / ``OpaqueEpilogue`` —
arbitrary user callables that may read any non-resident state) fall back to
:func:`replicated_psum_walk` (correct, collective-heavy, not parity-exact).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.api import EdgeCtx, SamplingSpec
from repro.core import backend as bk
from repro.core import methods as mt
from repro.core import select as sel
from repro.core import transition as tp
from repro.core.engine import WalkResult, _degree, _edge_ctx, flat_method_plan
from repro.distributed.sharding import shard_map_compat
from repro.graph.csr import CSRGraph
from repro.graph.partition import (
    PartitionMap,
    hub_edge_layout,
    hybrid_host_csr,
    localize_hybrid,
    partition_by_vertex_range,
    pid_of_device,
    place_hub_edges,
    select_hubs,
)
from repro.shard import exchange as ex

#: safety valve on the host drain loop (each block makes guaranteed progress
#: as long as exchange_slots >= 1, so this is never hit by a sane config)
_MAX_BLOCKS = 4096


def _per_entry(base_key, d, inst, valid, draw):
    """Per-entry counted RNG: ``draw(fold_in(base_key, d_e))[inst_e]``.

    ``draw(key) -> (W,)`` must reproduce one of the engine's per-step
    position-indexed vectors; indexing it at the walker's instance id makes
    the draw placement-independent.  The common case — every live entry in
    the batch at the same depth (no deferral backlog) — computes ONE ``(W,)``
    vector and gathers; mixed-depth batches pay a vmapped per-entry draw.
    """
    i = jnp.maximum(inst, 0)
    d0 = d[0]
    same = jnp.all(~valid | (d == d0))

    def cheap(_):
        return draw(jax.random.fold_in(base_key, d0))[i]

    def general(_):
        return jax.vmap(lambda dd, j: draw(jax.random.fold_in(base_key, dd))[j])(d, i)

    return jax.lax.cond(same, cheap, general, None)


def _carried_window_bias(graph, program, v, prev, d, curq, prow, deg_tgt):
    """The window-bias hook closed over carried walker state.

    Mirrors ``engine._window_bias_fn`` except that prev-neighbor membership
    is an exact compare against the CARRIED ``(B, prow_w)`` neighbor row of
    ``prev`` (``-2``-padded, gathered at the source shard) instead of a
    binary search over a resident CSR — identical booleans, no replicated
    adjacency.  ``needs_deg_u`` hooks gather the replicated per-edge
    target-degree lane at the window's edge positions (``eidx``) — the same
    integers the engine's ``_degree(graph, u)`` row lookup produces, since
    ``deg_tgt[e] = deg(indices[e])`` on the full graph by construction.
    """
    wb = program.bias
    deg_v = _degree(graph, curq)
    e_hi = deg_tgt.shape[0] - 1

    def bias_of(u, w, mask, eidx=None):
        if wb.needs_deg_u:
            du = jnp.where(mask, deg_tgt[jnp.clip(eidx, 0, e_hi)], 0)
        else:  # declared unused — skip the window-wide lane gather
            du = jnp.zeros(u.shape, jnp.int32)
        ipn = None
        if wb.needs_prev_neighbors:
            ipn = (
                jnp.any(u[..., :, None] == prow[..., None, :], axis=-1)
                & mask
                & (prev >= 0)[..., None]
                & (u >= 0)
            )
        ctx = EdgeCtx(
            v=v, u=u, weight=w, deg_v=deg_v,
            deg_u=du, prev=prev,
            is_prev_neighbor=ipn, depth=d[..., None],
        )
        return wb.fn(ctx)

    return bias_of


def _selected_deg(iglob, deg_tgt, st, dg, u, steps):
    """deg(u) of the SELECTED neighbor via the replicated degree lane.

    The pick kernels return the selected vertex id, not its edge position,
    so locate ``u`` by binary search in the current row's global-id slice
    ``iglob[st : st + dg]`` — destination-sorted by ``csr_from_edges``, an
    ordering both the resident and the hub-replicated copy preserve — and
    read ``deg_tgt`` there.  Parallel duplicate edges share a target (and
    therefore a degree), so any match position is correct.  ``steps`` must
    satisfy ``2**steps >= max row degree``; dead walkers (``u < 0``) read a
    harmless 1 (masked downstream).
    """
    e_hi = iglob.shape[0] - 1
    lo = jnp.zeros_like(dg)
    hi = dg

    def body(_, lohi):
        lo, hi = lohi
        open_ = lo < hi
        mid = (lo + hi) // 2
        val = iglob[jnp.clip(st + mid, 0, e_hi)]
        go_right = val < u
        lo = jnp.where(open_ & go_right, mid + 1, lo)
        hi = jnp.where(open_ & ~go_right, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(st + lo, 0, e_hi)
    found = (lo < dg) & (iglob[pos] == u) & (u >= 0)
    return jnp.where(found, deg_tgt[jnp.clip(pos, 0, deg_tgt.shape[0] - 1)], 1)


# ---------------------------------------------------------------------------
# The compiled drain block (one jit per config; cached)
# ---------------------------------------------------------------------------

_DRAIN_CACHE: dict = {}
#: bound on cached drain traces — like every jit-static-spec entry point in
#: this repo (engine.random_walk, oom._drain), a FRESHLY CONSTRUCTED spec is
#: a new trace key (its hooks are new closures), so callers should reuse
#: spec objects across calls; the bound turns a caller that doesn't into
#: steady-state recompiles instead of an unbounded cache leak
_DRAIN_CACHE_MAX = 64


def _drain_block(
    mesh: Mesh, axis: str, *, spec: SamplingSpec, be: str, num_devices: int,
    num_inst: int, depth: int, cap: int, slots: int, prow_w: int,
    buckets: tuple, use_chunked: bool, rounds: int, range_size: int,
    num_hubs: int, sub_rounds: int, mh_steps: int, methods: tuple = (),
):
    """Build (or fetch) the jitted shard_map drain for one static config."""
    cfg = (mesh, axis, spec, be, num_devices, num_inst, depth, cap, slots,
           prow_w, buckets, use_chunked, rounds, range_size, num_hubs,
           sub_rounds, mh_steps, methods)
    if cfg in _DRAIN_CACHE:
        return _DRAIN_CACHE[cfg]
    while len(_DRAIN_CACHE) >= _DRAIN_CACHE_MAX:
        _DRAIN_CACHE.pop(next(iter(_DRAIN_CACHE)))

    program = tp.lower(spec)
    mode = program.mode
    needs_prev = prow_w > 0
    nfields = 5 if needs_prev else 4
    num_dest = num_devices
    use_mh = isinstance(program.epilogue, tp.MHAcceptEpilogue)
    phantom = range_size + 2 * num_hubs

    use_alias = any(m == "alias" for m in methods)
    use_rej = any(m == "rejection" for m in methods)

    def body(indptr, iloc, iglob, wts, bias, vlo, prob, alias, rowmax,
             deg_tgt, hubs, qfields, qcount, qdropped, dfields, dcount,
             walks, key, seeds, limits):
        indptr, iloc, iglob, wts, bias, vlo0 = (
            indptr[0], iloc[0], iglob[0], wts[0], bias[0], vlo[0]
        )
        # partition-local slices of the full-graph method tables; None'd out
        # when the plan never reads them, exactly like the engine's pytree
        tbl = mt.MethodTables(
            prob=prob[0] if use_alias else None,
            alias=alias[0] if use_alias else None,
            row_max=rowmax[0] if use_rej else None,
        )
        deg_tgt0 = deg_tgt[0]
        qfields = tuple(f[0] for f in qfields)
        dfields = tuple(f[0] for f in dfields)
        qcount, qdropped, dcount = qcount[0], qdropped[0], dcount[0]
        local = CSRGraph(indptr=indptr, indices=iloc, weights=wts)
        padded = bk.pad_walk_csr(iglob, bias, buckets)

        def rowid(x):
            return localize_hybrid(x, vlo0, range_size, hubs, num_hubs)

        def sub_step(carry, _):
            q, defer, walks, stats = carry
            # throttle the pop so (deferred + newly stepped) fits one batch
            entries, taken, q = ex.queue_pop(q, cap, limit=cap - defer.count)
            v, inst, d = entries[0], entries[1], entries[2]
            prev = entries[3]
            prow = entries[4] if needs_prev else None
            valid = inst >= 0
            curq = jnp.where(valid, rowid(v), -1)

            # -- one walk step, on the engine's exact counted RNG stream ----
            def u_draw(kd):  # fold_in(kstep, 1) -> fold_in(·, 0): bucket pick
                return jax.random.uniform(
                    jax.random.fold_in(jax.random.fold_in(kd, 1), 0),
                    (num_inst,), dtype=jnp.float32)

            def tail_draw(kd):  # fold_in(kstep, 1) -> fold_in(·, 1): tail
                return jax.random.uniform(
                    jax.random.fold_in(jax.random.fold_in(kd, 1), 1),
                    (num_inst,), dtype=jnp.float32)

            r0 = _per_entry(key, d, inst, valid, u_draw)
            tail = _per_entry(key, d, inst, valid, tail_draw) if use_chunked else None
            if mode == "flat" and methods:
                # adaptive selection (DESIGN.md §13): the plan was computed
                # from the SAME full-graph bias as the in-memory engine, so
                # supplying the engine's counted streams (instance-indexed)
                # keeps the sharded walk bit-identical per method
                rej = None
                if use_rej:
                    def rej_draw(c):
                        def drawfn(kd):  # fold_in(kstep,1) -> fold_in(·,2) -> c
                            return jax.random.uniform(
                                jax.random.fold_in(jax.random.fold_in(
                                    jax.random.fold_in(kd, 1), 2), c),
                                (num_inst,), dtype=jnp.float32)
                        return drawfn

                    cols = [
                        _per_entry(key, d, inst, valid, rej_draw(c))
                        for c in range(2 * sel.REJECT_ITERS)
                    ]
                    rej = jnp.stack(cols, axis=-1).reshape(
                        cols[0].shape + (sel.REJECT_ITERS, 2)
                    )
                u = bk.walk_step_adaptive(
                    key, indptr, iglob, bias, padded, curq,
                    buckets=buckets, use_chunked=use_chunked,
                    methods=methods, tables=tbl, backend=be,
                    rand=r0, tail_rand=tail, rej_rand=rej,
                )
            elif mode == "flat":
                if be == "pallas":
                    u = bk.walk_step_bucketed(
                        key, indptr, iglob, bias, padded, curq,
                        buckets=buckets, use_chunked=use_chunked,
                        rand=r0, tail_rand=tail,
                    )
                else:
                    u = bk.walk_step_flat_reference(
                        key, indptr, iglob, bias, padded, curq,
                        buckets=buckets, use_chunked=use_chunked,
                        max_degree=None, rand=r0, tail_rand=tail,
                    )
            else:
                bias_of = _carried_window_bias(
                    local, program, v, prev, d, curq, prow, deg_tgt0
                )
                u = bk.walk_step_bucketed_window(
                    key, indptr, iglob, wts, padded, curq, bias_of,
                    buckets=buckets, use_chunked=use_chunked, backend=be,
                    rand=r0, tail_rand=tail,
                )

            # -- epilogue (engine's fused post-select step, instance-keyed) --
            epi = program.epilogue
            if isinstance(epi, tp.TeleportEpilogue):
                def tel_draw(kd):
                    kj, _ = jax.random.split(jax.random.fold_in(kd, 2))
                    return jax.random.uniform(kj, (num_inst,))

                teleport = _per_entry(key, d, inst, valid, tel_draw) < epi.prob
                if epi.target == "uniform":
                    def tgt_draw(kd):
                        _, kv = jax.random.split(jax.random.fold_in(kd, 2))
                        return jax.random.randint(
                            kv, (num_inst,), 0, epi.num_vertices)

                    tgt = _per_entry(key, d, inst, valid, tgt_draw)
                elif epi.target == "fixed":
                    tgt = jnp.full_like(u, epi.vertex)
                else:  # "home"
                    tgt = seeds[jnp.maximum(inst, 0)].astype(jnp.int32)
                nxt = jnp.where(teleport & (u >= 0), tgt, u)
            elif use_mh:
                # MH-accept, owner-routed: deg(v) is the current row's true
                # degree (resident or hub copy — both full rows) and deg(u)
                # comes off the replicated target-degree lane; the counted
                # uniform and the acceptance arithmetic (transition.mh_stay)
                # are the engine's own, so the stay/move bit is identical
                def acc_draw(kd):
                    return jax.random.uniform(
                        jax.random.fold_in(kd, 2), (num_inst,))

                st_mh = indptr[jnp.maximum(curq, 0)]
                dg_mh = indptr[jnp.maximum(curq, 0) + 1] - st_mh
                deg_u = _selected_deg(iglob, deg_tgt0, st_mh, dg_mh, u, mh_steps)
                acc = _per_entry(key, d, inst, valid, acc_draw)
                stay = tp.mh_stay(acc, dg_mh, deg_u)
                nxt = jnp.where(stay & (v >= 0) & (u >= 0), v, u)
            else:  # IdentityEpilogue (opaque rejected upstream)
                nxt = u
            nxt = jnp.where(u >= 0, nxt, -1)

            ok = valid & (nxt >= 0)
            walks = walks.at[
                jnp.where(ok, inst, num_inst), jnp.maximum(d, 0) + 1
            ].set(nxt, mode="drop")
            cont = ok & (d + 1 < limits[jnp.maximum(inst, 0)])

            # -- survivors: resident/hub stay local, cold rows defer --------
            new_entry = [nxt, inst, d + 1, v]
            if needs_prev:
                # the NEXT step's is_prev_neighbor needs N(v): gather v's
                # row here, the one shard that holds it, and carry it along
                offs = jnp.arange(prow_w, dtype=jnp.int32)
                st = indptr[jnp.maximum(curq, 0)]
                dgv = _degree(local, curq)
                rmask = (offs[None, :] < dgv[:, None]) & valid[:, None]
                new_entry.append(
                    jnp.where(rmask, iglob[jnp.where(rmask, st[:, None] + offs, 0)], -2)
                )
            stay_local = rowid(nxt) != phantom
            q = ex.queue_push(q, tuple(new_entry), cont & stay_local)
            defer = ex.queue_push(defer, tuple(new_entry), cont & ~stay_local)
            hub_hops = jnp.sum((valid & (curq > range_size)).astype(jnp.int32))
            stats = stats + jnp.stack(
                [jnp.zeros((), jnp.int32), hub_hops,
                 jnp.sum(valid.astype(jnp.int32))]
            )
            return (q, defer, walks, stats), None

        def do_round(carry, defer_live):
            q, defer, walks, stats = carry

            # -- flush deferred emigrants through ONE tiled all_to_all ------
            def exch(args):
                q, defer, stats = args
                dmask = jnp.arange(cap, dtype=jnp.int32) < defer.count
                dest = pid_of_device(defer.fields[0], range_size, num_dest)
                send, sent, leftover, left_count = ex.route_by_owner(
                    defer.fields, dest, dmask, num_dest, slots
                )
                recv = ex.all_to_all_fields(send, axis)
                rflat = tuple(
                    r.reshape((num_dest * slots,) + r.shape[2:]) for r in recv
                )
                q = ex.queue_push(q, rflat, rflat[1] >= 0)
                defer = ex.ShardQueue(
                    tuple(f[:cap] for f in leftover), left_count, defer.dropped
                )
                z = jnp.zeros((), jnp.int32)
                return q, defer, stats + jnp.stack([jnp.sum(sent), z, z])

            q, defer, stats = jax.lax.cond(
                defer_live > 0, exch, lambda a: a, (q, defer, stats)
            )
            # -- overlap: local sub-rounds drain resident + hub hops --------
            # unrolled at trace level so each sub-round is one inlined step
            carry = (q, defer, walks, stats)
            for _ in range(sub_rounds):
                carry, _ = sub_step(carry, None)
            return carry

        def round_step(carry, _):
            q, defer, walks, stats = carry
            # one fused psum: [live anywhere, deferred anywhere] — gates the
            # whole round AND lets an all-local round skip its collective
            tot = jax.lax.psum(
                jnp.stack([q.count + defer.count, defer.count]), axis
            )
            carry = jax.lax.cond(
                tot[0] > 0, lambda c: do_round(c, tot[1]), lambda c: c, carry
            )
            return carry, None

        q0 = ex.ShardQueue(qfields, qcount, qdropped)
        d0 = ex.ShardQueue(dfields, dcount, jnp.zeros((), jnp.int32))
        stats0 = jnp.zeros((3,), jnp.int32)
        (q, defer, walks, stats), _ = jax.lax.scan(
            round_step, (q0, d0, walks, stats0), None, length=rounds
        )
        live = jax.lax.psum(q.count + defer.count, axis)
        walks = jax.lax.pmax(walks, axis)
        return (
            tuple(f[None] for f in q.fields), q.count[None], q.dropped[None],
            tuple(f[None] for f in defer.fields), defer.count[None],
            walks, live, stats[None],
        )

    dshard = P(axis)
    rep = P()
    in_specs = (
        dshard, dshard, dshard, dshard, dshard, dshard,  # graph arrays
        dshard, dshard, dshard,                          # method tables
        dshard, rep,                                     # deg lane, hub ids
        (dshard,) * nfields, dshard, dshard,             # queue
        (dshard,) * nfields, dshard,                     # deferred
        rep, rep, rep, rep,                              # walks, key, seeds, limits
    )
    out_specs = (
        (dshard,) * nfields, dshard, dshard,
        (dshard,) * nfields, dshard,
        rep, rep, dshard,
    )
    fn = jax.jit(
        shard_map_compat(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )
    _DRAIN_CACHE[cfg] = fn
    return fn


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def sharded_random_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
    backend: bk.Backend = "auto",
    depth_limits: Optional[np.ndarray] = None,
    exchange_slots: Optional[int] = None,
    queue_capacity: Optional[int] = None,
    rounds_per_block: Optional[int] = None,
    hub_bytes: Optional[int] = None,
    sub_rounds: int = 1,
) -> WalkResult:
    """Random walk over a range-sharded graph: owners step, emigrants route.

    Each device of ``mesh`` (along ``axis``) holds one vertex-range shard of
    ``graph`` — per-device CSR footprint ∝ 1/D plus the replicated hub
    region — and walkers migrate to the shard owning their frontier vertex
    only when it is neither resident nor hub-replicated.  For every
    non-opaque transition program the result is **bit-identical** to
    single-device ``engine.random_walk(graph, seeds, key, ...)`` with the
    same arguments, on both backends (the parity contract in the module
    docstring; for window programs ``max_degree`` must be the true max row
    degree, the same contract the engine's exact window bucket plan already
    imposes).  Programs with opaque hooks fall back to
    :func:`replicated_psum_walk`.

    ``depth_limits`` (optional ``(W,)``, values in ``[0, depth]``) stops
    instance ``i`` after its own number of steps — the batched service packs
    heterogeneous requests into one launch with it.  ``-1`` seeds are
    padding and emit all--1 rows.

    ``exchange_slots`` bounds the per-destination send buffer of one round;
    walkers past it are deferred to later rounds, never dropped (the queue
    itself defaults to holding the whole walker population, so ``dropped``
    stays zero).  ``rounds_per_block`` sizes the compiled scan; the host
    re-invokes the block while any shard still holds live walkers.

    ``hub_bytes`` budgets the per-device replicated hub region (default:
    roughly half a shard's edge footprint; ``0`` disables replication —
    the pure range-shard layout of the earlier design).  ``sub_rounds``
    local sub-rounds run between consecutive exchanges, so resident- and
    hub-destined walkers take several steps per collective.  Migration
    COUNT is trajectory-determined (bit-identical walks), so extra
    sub-rounds never reduce exchange volume — they amortize collective
    *latency* on real multi-chip meshes at the price of extra fixed-shape
    step launches; the default is 1, which is also what forced-host-device
    runs (no network latency to hide) should use.

    The returned :class:`~repro.core.engine.WalkResult` carries a ``stats``
    dict (exchange traffic, hub/resident hop split, layout footprint) —
    the observability the BENCH flatness gate and the hub-efficacy
    benchmarks read.
    """
    program = tp.lower(spec)
    mode = program.mode
    owner_ok = mode != "opaque" and not isinstance(
        program.epilogue, tp.OpaqueEpilogue
    )
    seeds_np = np.asarray(seeds, dtype=np.int32)
    num_inst = int(seeds_np.shape[0])
    if depth_limits is None:
        limits_np = np.full((num_inst,), depth, np.int32)
    else:
        limits_np = np.asarray(depth_limits, dtype=np.int32)
        if limits_np.shape != (num_inst,):
            raise ValueError(
                f"depth_limits shape {limits_np.shape} != ({num_inst},)"
            )
        if limits_np.size and (limits_np.min() < 0 or limits_np.max() > depth):
            raise ValueError(
                f"depth_limits must lie in [0, depth={depth}], got "
                f"[{limits_np.min()}, {limits_np.max()}]"
            )

    if not owner_ok:
        walks = replicated_psum_walk(
            mesh, graph, jnp.asarray(seeds_np), key,
            depth=depth, spec=spec, max_degree=max_degree, axis=axis,
        )
        walks = jnp.where(
            jnp.arange(depth + 1)[None, :] <= jnp.asarray(limits_np)[:, None],
            walks, -1,
        )
        lengths = jnp.sum(walks >= 0, axis=-1)
        return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))

    if depth < 1 or num_inst == 0:
        walks = jnp.full((num_inst, depth + 1), -1, jnp.int32)
        if num_inst:
            walks = walks.at[:, 0].set(jnp.asarray(seeds_np))
        lengths = jnp.sum(walks >= 0, axis=-1)
        return WalkResult(walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)))

    num_devices = int(mesh.shape[axis])
    be = bk.resolve_backend(backend)
    if mode == "flat":
        buckets, use_chunked = bk.walk_bucket_plan(max_degree)
    else:
        buckets, use_chunked = bk.walk_bucket_plan_window(max_degree)
    seg_big = max(buckets)
    pm = PartitionMap.create(graph.num_vertices, num_devices)
    parts = partition_by_vertex_range(graph, num_devices)
    needs_prev = mode == "window" and program.bias.needs_prev_neighbors
    use_mh = isinstance(program.epilogue, tp.MHAcceptEpilogue)
    needs_degu = mode == "window" and program.bias.needs_deg_u
    indptr_np = np.asarray(graph.indptr)
    indices_np = np.asarray(graph.indices)
    weights_np = np.asarray(graph.weights)
    true_max_deg = int(np.diff(indptr_np).max()) if indptr_np.size > 1 else 0
    prow_w = true_max_deg if needs_prev else 0
    mh_steps = min(32, max(1, true_max_deg.bit_length())) if use_mh else 1

    # -- hub selection: replicate the hot top-degree rows on every device ---
    num_edges = int(indices_np.shape[0])
    if num_devices > 1:
        if hub_bytes is None:
            # default ≈ half a shard's replicated-lane footprint: high enough
            # to catch power-law hubs, low enough to keep HBM ∝ 1/D
            hb = (4 * 7 * num_edges) // (2 * num_devices)
        else:
            hb = int(hub_bytes)
    else:
        hb = 0  # single device: everything is already resident
    hubs_np = select_hubs(indptr_np, hb, seg_big)
    num_hubs = int(hubs_np.shape[0])

    # -- materialize shards: common padded shape, global block alignment ----
    pad_v = pm.range_size
    pad_e_local = max((p.edge_lo % seg_big) + p.num_edges for p in parts)
    hub_lo = -(-pad_e_local // seg_big) * seg_big
    hub_starts, hub_end = hub_edge_layout(indptr_np, hubs_np, hub_lo, seg_big)
    pad_e = max(pad_e_local, hub_end)
    phantom = pad_v + 2 * num_hubs
    host_csrs = [
        hybrid_host_csr(
            p, pad_v, pad_e, seg_big, hubs_np, hub_starts,
            indptr_np, indices_np, weights_np,
        )
        for p in parts
    ]

    def _edge_lane(full):
        """Slice a full-graph per-edge lane into every shard's hybrid layout."""
        lane = np.zeros((num_devices, pad_e), full.dtype)
        for i, p in enumerate(parts):
            lead = p.edge_lo % seg_big
            lane[i, lead : lead + p.num_edges] = full[
                p.edge_lo : p.edge_lo + p.num_edges
            ]
            if num_hubs:
                lane[i] = place_hub_edges(
                    lane[i], full, indptr_np, hubs_np, hub_starts
                )
        return lane

    if mode == "flat":
        # flat biases may read non-resident state (e.g. neighbor degrees):
        # evaluate ONCE on the full graph, slice per shard — bit-equal to the
        # engine's full-graph evaluation by construction
        fb_full = np.asarray(program.bias.fn(graph), dtype=np.float32)
        bias_np = _edge_lane(fb_full)
    else:
        bias_np = np.stack([h[3] for h in host_csrs])  # edge weights

    # -- adaptive selection plan (DESIGN.md §13): planned from the SAME
    # full-graph bias as the in-memory engine (same cache entry), so the
    # method per cohort — and therefore every drawn bit — matches
    # single-device random_walk exactly.  Tables are sliced per shard the
    # way the bias is: alias redirects are row-local (row slicing preserves
    # them, hub rows are copied whole) and the lead padding keeps global
    # block alignment.
    sel_methods: tuple = ()
    tables_full = mt.EMPTY_TABLES
    if mode == "flat":
        sel_methods, tables_full = flat_method_plan(graph, program, max_degree)
        if mt.is_trivial(sel_methods):
            sel_methods = ()
    prob_np = np.zeros((num_devices, pad_e), np.float32)
    alias_np = np.zeros((num_devices, pad_e), np.int32)
    rowmax_np = np.zeros((num_devices, phantom + 1), np.float32)
    if tables_full.prob is not None:
        prob_np = _edge_lane(np.asarray(tables_full.prob))
        alias_np = _edge_lane(np.asarray(tables_full.alias))
    if tables_full.row_max is not None:
        rm_full = np.asarray(tables_full.row_max)
        for i, p in enumerate(parts):
            rowmax_np[i, : p.num_vertices] = rm_full[p.vertex_lo : p.vertex_hi]
            if num_hubs:
                rowmax_np[i, pad_v + 1 + 2 * np.arange(num_hubs)] = rm_full[hubs_np]

    # -- replicated target-degree lane: deg(u) for any candidate edge, read
    # at the SOURCE shard (needs_deg_u window hooks, MH-accept) — degrees
    # never cross the wire
    if use_mh or needs_degu:
        dt_full = np.diff(indptr_np).astype(np.int32)[indices_np]
        dt_np = _edge_lane(dt_full)
    else:
        dt_np = np.zeros((num_devices, 1), np.int32)

    shardspec = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    put_s = functools.partial(jax.device_put, device=shardspec)
    indptr_s = put_s(jnp.asarray(np.stack([h[0] for h in host_csrs])))
    iloc_s = put_s(jnp.asarray(np.stack([h[1] for h in host_csrs])))
    iglob_s = put_s(jnp.asarray(np.stack([h[2] for h in host_csrs])))
    wts_s = put_s(jnp.asarray(np.stack([h[3] for h in host_csrs])))
    bias_s = put_s(jnp.asarray(bias_np))
    vlo_s = put_s(jnp.asarray([p.vertex_lo for p in parts], jnp.int32))
    prob_s = put_s(jnp.asarray(prob_np))
    alias_s = put_s(jnp.asarray(alias_np))
    rowmax_s = put_s(jnp.asarray(rowmax_np))
    deg_tgt_s = put_s(jnp.asarray(dt_np))
    hubs_d = jax.device_put(
        jnp.asarray(
            hubs_np if num_hubs else np.full((1,), -1, np.int64), jnp.int32
        ),
        rep,
    )

    walks0 = np.full((num_inst, depth + 1), -1, np.int32)
    walks0[:, 0] = seeds_np

    # -- initial queues: every live seed starts at its owner ----------------
    cap = num_inst if queue_capacity is None else int(queue_capacity)
    if cap < 1:
        raise ValueError(f"queue_capacity must be >= 1, got {cap}")
    slots = cap if exchange_slots is None else int(exchange_slots)
    if slots < 1:
        raise ValueError(f"exchange_slots must be >= 1, got {slots}")
    slots = min(slots, cap)
    widths = (0, 0, 0, 0) + ((prow_w,) if needs_prev else ())
    live0 = (seeds_np >= 0) & (limits_np > 0)
    owners = pm.pid_of(np.maximum(seeds_np, 0))
    qf0 = [
        np.full((num_devices, cap) if w == 0 else (num_devices, cap, w),
                -1 if w == 0 else -2, np.int32)
        for w in widths
    ]
    qc0 = np.zeros((num_devices,), np.int32)
    for dv in range(num_devices):
        idxs = np.nonzero(live0 & (owners == dv))[0].astype(np.int32)
        k = len(idxs)
        if k > cap:
            raise ValueError(
                f"queue_capacity={cap} cannot hold the {k} seeds owned by "
                f"shard {dv}; raise queue_capacity (default: num instances)"
            )
        qf0[0][dv, :k] = seeds_np[idxs]
        qf0[1][dv, :k] = idxs
        qf0[2][dv, :k] = 0
        qf0[3][dv, :k] = -1
        qc0[dv] = k

    qfields = tuple(put_s(jnp.asarray(f)) for f in qf0)
    qcount = put_s(jnp.asarray(qc0))
    qdropped = put_s(jnp.zeros((num_devices,), jnp.int32))
    dfields = tuple(
        put_s(jnp.full((num_devices, cap) if w == 0 else (num_devices, cap, w),
                       -1 if w == 0 else -2, jnp.int32))
        for w in widths
    )
    dcount = put_s(jnp.zeros((num_devices,), jnp.int32))
    walks = jax.device_put(jnp.asarray(walks0), rep)
    seeds_d = jax.device_put(jnp.asarray(seeds_np), rep)
    limits_d = jax.device_put(jnp.asarray(limits_np), rep)
    key = jax.device_put(key, rep)

    sub = max(int(sub_rounds), 1)
    rounds = int(rounds_per_block) if rounds_per_block else depth + 1
    drain = _drain_block(
        mesh, axis, spec=spec, be=be, num_devices=num_devices,
        num_inst=num_inst, depth=depth, cap=cap, slots=slots, prow_w=prow_w,
        buckets=buckets, use_chunked=use_chunked, rounds=max(rounds, 1),
        range_size=pm.range_size, num_hubs=num_hubs, sub_rounds=sub,
        mh_steps=mh_steps, methods=sel_methods,
    )

    blocks = 0
    stats_acc = np.zeros(3, np.int64)
    while True:
        qfields, qcount, qdropped, dfields, dcount, walks, live, dstats = drain(
            indptr_s, iloc_s, iglob_s, wts_s, bias_s, vlo_s,
            prob_s, alias_s, rowmax_s, deg_tgt_s, hubs_d,
            qfields, qcount, qdropped, dfields, dcount,
            walks, key, seeds_d, limits_d,
        )
        blocks += 1
        stats_acc += np.sum(
            np.asarray(jax.device_get(dstats), np.int64), axis=0
        )
        if int(jax.device_get(live)) == 0:
            break
        if blocks >= _MAX_BLOCKS:
            raise RuntimeError(
                f"sharded drain made no global progress after {blocks} "
                f"blocks — exchange_slots={slots} too small?"
            )
    dropped = int(np.sum(jax.device_get(qdropped)))
    if dropped:
        raise RuntimeError(
            f"sharded frontier queues dropped {dropped} walkers — "
            f"queue_capacity={cap} is below the live walker population"
        )
    entry_bytes = ex.entry_nbytes(widths)
    stats = {
        "num_devices": num_devices,
        "exchanged_entries": int(stats_acc[0]),
        "exchange_bytes": int(stats_acc[0]) * entry_bytes,
        "entry_bytes": entry_bytes,
        "hub_hops": int(stats_acc[1]),
        "resident_hops": int(stats_acc[2] - stats_acc[1]),
        "num_hubs": num_hubs,
        "hub_replicated_edges": (
            int(np.sum(np.diff(indptr_np)[hubs_np])) if num_hubs else 0
        ),
        "sub_rounds": sub,
        "blocks": blocks,
    }
    lengths = jnp.sum(walks >= 0, axis=-1)
    return WalkResult(
        walks, lengths, jnp.sum(jnp.maximum(lengths - 1, 0)), stats
    )


# ---------------------------------------------------------------------------
# Replicated-state fallback (opaque-hook programs only) + shard staging helper
# ---------------------------------------------------------------------------


def shard_graph_for_mesh(graph: CSRGraph, num_devices: int):
    """Range-partition a CSR into per-device stacked full-V-indptr CSRs.

    Returns (indptr_stack (D, V+1), indices_stack (D, Emax), weights_stack)
    where each device's slice covers the full vertex-id space with empty rows
    for unowned vertices (so global ids index directly) and edge arrays are
    padded to the max partition size.  Only the :func:`replicated_psum_walk`
    fallback uses this layout; the owner-routed path ships compact hybrid
    CSRs instead (O(V/D + E_D) plus the hub region, DESIGN.md §12/§14).
    """
    parts = partition_by_vertex_range(graph, num_devices)
    v = graph.num_vertices
    emax = max(p.num_edges for p in parts)
    indptrs, indices, weights = [], [], []
    for p in parts:
        full = np.zeros(v + 1, np.int32)
        full[p.vertex_lo + 1 : p.vertex_hi + 1] = p.indptr[1:]
        full[p.vertex_hi + 1 :] = p.indptr[-1]
        indptrs.append(full)
        indices.append(np.pad(p.indices, (0, emax - p.num_edges), constant_values=0).astype(np.int32))
        weights.append(np.pad(p.weights, (0, emax - p.num_edges)).astype(np.float32))
    return (
        jnp.asarray(np.stack(indptrs)),
        jnp.asarray(np.stack(indices)),
        jnp.asarray(np.stack(weights)),
    )


def replicated_psum_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> jax.Array:
    """Walk over a device-sharded graph: owners advance, ``psum`` merges.

    Returns walks (I, depth+1).  Per step each device computes successors for
    walkers whose current vertex it owns (others contribute zeros) and a
    single integer psum replicates the advanced state.  The OPAQUE-program
    fallback of :func:`sharded_random_walk` — the only programs left outside
    the owner-routed envelope: the dense gather evaluates arbitrary user
    hooks that may read any non-resident state, at the cost of replicated
    walker state and one psum per step, and it draws its own RNG pattern
    (not parity-exact with the single-device engine).
    """
    ndev = mesh.shape[axis]
    nvert = graph.num_vertices
    program = tp.lower(spec)
    indptr_s, indices_s, weights_s = shard_graph_for_mesh(graph, ndev)
    # same cached bounds the partitioner used — lo/hi must match the shards
    bounds = PartitionMap.create(nvert, ndev).bounds.astype(np.int32)
    lo = jnp.asarray(bounds[:-1])
    hi = jnp.asarray(bounds[1:])

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=P(),
    )
    def _run(indptr, indices, wts, lo, hi, seeds, key):
        local = CSRGraph(indptr[0], indices[0], wts[0])
        lo0, hi0 = lo[0], hi[0]
        home = seeds.astype(jnp.int32) if program.carries_home else None

        def step(carry, it):
            cur, prev = carry
            own = (cur >= lo0) & (cur < hi0)
            safe = jnp.where(own, cur, lo0)  # in-range dummy for gathers
            ctx, mask = _edge_ctx(local, safe, prev, it, max_degree, spec.needs_prev_neighbors)
            biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
            kstep = jax.random.fold_in(key, it)  # same key on all devices
            idx = sel.select_with_replacement(jax.random.fold_in(kstep, 1), biases, mask, 1)[..., 0]
            u = jnp.take_along_axis(ctx.u, idx[..., None], axis=-1)[..., 0]
            alive = own & (cur >= 0) & jnp.any(mask, axis=-1)
            # post-select update through the lowered epilogue (shared with
            # the in-memory engines and the OOM drain, DESIGN.md §10)
            u = jnp.where(
                alive,
                tp.apply_epilogue(
                    jax.random.fold_in(kstep, 2), program, spec, ctx, u, home
                ),
                -1,
            )
            contrib = jnp.where(own, jnp.where(alive, u, -1), 0)
            dead = jax.lax.psum(jnp.where(own, jnp.where(alive, 0, 1), 0), axis)
            nxt = jax.lax.psum(contrib, axis)  # exactly one owner contributes
            nxt = jnp.where((dead > 0) | (cur < 0), -1, nxt)
            return (nxt, cur), nxt

        (_, _), path = jax.lax.scan(
            step, (seeds.astype(jnp.int32), jnp.full(seeds.shape, -1, jnp.int32)), jnp.arange(depth)
        )
        return jnp.concatenate([seeds[None].astype(jnp.int32), path], 0).T

    return _run(indptr_s, indices_s, weights_s, lo, hi, seeds, key)
