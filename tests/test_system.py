"""End-to-end behaviour tests for the C-SAW system.

These exercise the public API the way the examples do: sample a graph,
compare against the paper's qualitative claims, and drive a tiny
sampling-fed training run.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import select as sel
from repro.core.engine import random_walk, traversal_sample
from repro.graph import powerlaw_graph, rmat_graph


def test_seps_pipeline_end_to_end():
    """SEPS metric accounting: sampled edges counted consistently."""
    g = rmat_graph(8, edge_factor=8, seed=0)
    seeds = jax.random.randint(jax.random.PRNGKey(0), (256,), 0, g.num_vertices)
    res = random_walk(g, seeds, jax.random.PRNGKey(1), depth=32,
                      spec=alg.deepwalk(), max_degree=g.max_degree())
    walks = np.asarray(res.walks)
    manual = sum(((row[:-1] >= 0) & (row[1:] >= 0)).sum() for row in walks)
    assert int(res.sampled_edges) == manual


def test_brs_beats_repeated_on_scale_free_graph():
    """Paper Fig. 10/11 claim, reproduced on a scale-free graph: biased
    neighbor sampling with BRS needs fewer retry iterations."""
    g = powerlaw_graph(1024, exponent=2.0, seed=4, weighted=True)
    pools = jax.random.randint(jax.random.PRNGKey(2), (64, 1), 0, g.num_vertices)
    spec = alg.biased_neighbor_sampling(neighbor_size=4, frontier_size=4)
    kw = dict(depth=2, spec=spec, max_degree=g.max_degree(),
              pool_capacity=128, max_vertices=g.num_vertices)
    brs = traversal_sample(g, pools, jax.random.PRNGKey(3), method="its_brs", **kw)
    rep = traversal_sample(g, pools, jax.random.PRNGKey(3), method="repeated", **kw)
    assert int(brs.iters) < int(rep.iters)
    # both sample a comparable number of edges
    assert abs(int(brs.num_edges.sum()) - int(rep.num_edges.sum())) < 0.2 * int(rep.num_edges.sum()) + 20


def test_api_expressiveness_table1():
    """Every Table-I algorithm is expressible and runs (paper's API claim)."""
    g = powerlaw_graph(256, seed=6, weighted=True)
    key = jax.random.PRNGKey(0)
    walk_algos = ["deepwalk", "biased_rw", "weighted_rw", "node2vec", "mhrw"]
    for name in walk_algos:
        spec = alg.ALGORITHMS[name]()
        res = random_walk(g, jnp.zeros((4,), jnp.int32), key, depth=4,
                          spec=spec, max_degree=g.max_degree())
        assert res.walks.shape == (4, 5)
    trav_algos = ["neighbor_biased", "neighbor_unbiased", "forest_fire", "layer", "snowball", "mdrw"]
    for name in trav_algos:
        spec = alg.ALGORITHMS[name]()
        pools = jnp.tile(jnp.array([[1, 2, 3]], jnp.int32), (4, 1))
        res = traversal_sample(g, pools, key, depth=2, spec=spec,
                               max_degree=g.max_degree(), pool_capacity=64,
                               max_vertices=g.num_vertices if spec.track_visited else 0)
        assert int(res.num_edges.sum()) >= 0


def test_gumbel_mode_distributionally_equivalent():
    """Beyond-paper Gumbel top-k equals sequential ITS w/o replacement."""
    biases = jnp.array([5.0, 3.0, 1.0, 1.0])
    n = 30000

    def pair_counts(method, seed):
        res = sel.select_without_replacement(
            jax.random.PRNGKey(seed), jnp.tile(biases, (n, 1)), None, 2, method=method)
        arr = np.sort(np.asarray(res.indices), 1)
        return np.bincount(arr[:, 0] * 4 + arr[:, 1], minlength=16)

    gum = pair_counts("gumbel", 1)
    upd = pair_counts("updated", 2)
    tot = gum + upd
    keep = tot > 0
    stat = np.sum((gum[keep] - upd[keep]) ** 2 / tot[keep])
    assert stat < 25.0
