"""Out-of-memory sampling: workload-aware partition scheduling (paper §V).

The graph lives on the host in contiguous vertex-range partitions; only a
bounded number of partitions is resident in device memory at a time.  The
scheduler:

  1. counts *active* frontier vertices per partition (paper Fig. 8 step 1),
  2. transfers the partitions with the most workload first (step 2) through a
     double-buffered ``TransferEngine`` (the cudaMemcpyAsync analogue:
     ``prefetch`` starts the next scheduled partition's async device_put
     while the current one drains),
  3. samples a resident partition until its frontier queue drains, inserting
     successors into the owning partition's queue (cross-partition comm),
  4. repeats until no partition has active vertices (step 3).

Unlike the original host-loop implementation, the frontier is DEVICE
RESIDENT (``core.frontier``): one fixed-capacity queue per partition stacked
as ``(P, cap)`` flat arrays.  The per-partition drain is a single
``lax.scan`` over fixed-size chunks inside ONE jit per (partition shape,
spec, chunk) — partitions are padded to a common shape so every partition
shares the same trace — and cross-partition redistribution is one vectorized
scatter (:func:`frontier.push_many`).  Selection dispatches on the spec's
lowered transition program (``core.transition``, DESIGN.md §10): flat-bias
programs take the degree-bucketed walk fast path (Pallas kernels on
``backend="pallas"``, the bit-identical pure-jnp mirror on ``"reference"``),
window-bias programs (node2vec-class dynamic hooks) evaluate their hook per
degree bucket on gathered edge windows (``engine.walk_window_transition``),
epilogues (MH-accept / jump / restart) fuse into the shared post-select
step — so non-flat specs run out-of-memory on the fast path too; only
opaque programs use the dense gather step
(``engine.walk_gather_transition``).  Both backends consume identical RNG
bits, so walks and stats agree exactly.

The CPU still decides *which* partition to ship (as in the paper), but every
scheduling decision it acts on — partition order, per-partition budgets — is
computed on-device from the frontier counts (:func:`_plan`).

Batched multi-instance sampling (§V-C) merges entries of *all* instances into
one queue per partition (metadata: InstanceID, CurrDepth); disabling it
processes one instance's entries per chunk — the paper's Fig. 13 baseline.
Thread-block workload balancing (§V-B) becomes proportional chunk budgets
across co-resident partitions; per-chunk processed-entry counts are recorded
so benchmarks can report the paper's Fig. 14 imbalance metric.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import SamplingSpec
from repro.core import backend as bk
from repro.core import frontier
from repro.core import methods as mt
from repro.core import select as sel
from repro.core import transition as tp
from repro.core.engine import (
    _edge_ctx,
    walk_flat_transition,
    walk_gather_transition,
    walk_window_transition,
)
from repro.graph.partition import (
    DevicePartition,
    PartitionMap,
    RangePartition,
    pid_of_device,
)


@dataclasses.dataclass
class OOMStats:
    """Counters mirrored from the paper's out-of-memory evaluation."""

    partition_transfers: int = 0
    bytes_transferred: int = 0
    kernel_launches: int = 0
    entries_per_kernel: Optional[List[int]] = None
    sampled_edges: int = 0
    frontier_dropped: int = 0

    def __post_init__(self):
        if self.entries_per_kernel is None:
            self.entries_per_kernel = []

    def kernel_time_std(self) -> float:
        """Std of per-kernel workload (entry counts) — Fig. 14 proxy."""
        if not self.entries_per_kernel:
            return 0.0
        return float(np.std(np.asarray(self.entries_per_kernel, dtype=np.float64)))


class ResidentPartition(NamedTuple):
    """A partition materialized on device, plus its spec-derived edge bias."""

    dev: DevicePartition
    flat_bias: Optional[jax.Array]  # (E_P,) CSR-order bias, flat mode only
    # bucket seg -> padded (indices, bias-or-weights) arrays; bias in flat
    # mode, edge weights in window mode (the dynamic hook reads them)
    padded: Optional[dict]
    # adaptive-selection tables (DESIGN.md §13), partition-local layout:
    # alias prob/redirects over the padded edge axis, rejection envelopes
    # over the padded row axis.  EMPTY for its-only plans and non-flat modes.
    tables: mt.MethodTables = mt.EMPTY_TABLES


class TransferEngine:
    """Double-buffered host->device partition transfers with an LRU of
    ``capacity`` resident partitions (the 'GPU memory holds k partitions'
    constraint in the paper's Fig. 8 walkthrough)."""

    def __init__(
        self,
        partitions: List[RangePartition],
        materialize: Callable[[RangePartition], ResidentPartition],
        capacity: int,
    ):
        self.partitions = partitions
        self.capacity = max(1, capacity)
        self._materialize = materialize
        self._resident: dict[int, ResidentPartition] = {}
        self._lru: list[int] = []
        self.stats_transfers = 0
        self.stats_bytes = 0

    def fetch(self, pid: int) -> ResidentPartition:
        if pid in self._resident:
            self._lru.remove(pid)
            self._lru.append(pid)
            return self._resident[pid]
        if len(self._resident) >= self.capacity:
            evict = self._lru.pop(0)
            del self._resident[evict]
        res = self._materialize(self.partitions[pid])  # async DMA (device_put)
        self.stats_transfers += 1
        # count what actually ships: the padded local CSR plus the aligned
        # global-id edge array (not the unpadded host partition)
        self.stats_bytes += (
            res.dev.graph.indptr.nbytes + res.dev.graph.indices.nbytes
            + res.dev.graph.weights.nbytes + res.dev.indices_global.nbytes
        )
        self._resident[pid] = res
        self._lru.append(pid)
        return res

    def prefetch(self, pid: int) -> None:
        """Start the next scheduled partition's transfer while the current
        one drains.  ``jax.device_put`` is asynchronous, so the DMA overlaps
        the drain compute; no-op when capacity cannot hold both buffers."""
        if self.capacity < 2 or pid in self._resident:
            return
        self.fetch(pid)
        # keep the currently-draining partition most-recent so back-to-back
        # prefetches never evict it
        if len(self._lru) >= 2:
            self._lru[-1], self._lru[-2] = self._lru[-2], self._lru[-1]


@functools.partial(
    jax.jit, static_argnames=("workload_aware", "balance", "num_streams", "chunk")
)
def _plan(counts, *, workload_aware: bool, balance: bool, num_streams: int, chunk: int):
    """Array-level scheduling decisions from the device frontier counts.

    Returns ``(order, budgets)`` aligned with each other: the partition visit
    order (most-loaded first under workload-aware scheduling, fixed
    round-robin otherwise) and per-partition entry budgets (proportional to
    queued work under balancing), zero for partitions outside this round's
    ``num_streams`` active set.
    """
    num_parts = counts.shape[0]
    order = jnp.argsort(-counts) if workload_aware else jnp.arange(num_parts)
    oc = counts[order]
    act = oc > 0
    rank = jnp.cumsum(act.astype(jnp.int32)) - 1
    is_active = act & (rank < num_streams)
    total_active = jnp.sum(jnp.where(is_active, oc, 0))
    if balance:
        frac = oc.astype(jnp.float32) / jnp.maximum(total_active, 1).astype(jnp.float32)
        budgets = jnp.maximum(
            chunk, jnp.ceil(frac * (num_streams * chunk)).astype(jnp.int32)
        )
    else:
        budgets = jnp.full((num_parts,), chunk * num_streams, jnp.int32)
    return order, jnp.where(is_active, budgets, 0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "max_degree", "flat_max_degree", "depth", "chunk", "n_chunks",
        "be", "batched", "mode", "buckets", "use_chunked", "range_size",
        "methods",
    ),
    # the host never reuses the pre-call queues/walks — donate them so XLA
    # updates in place instead of copying both buffers every call (a no-op
    # with a one-time warning on CPU, real on TPU)
    donate_argnums=(1, 2),
)
def _drain(
    part: ResidentPartition,
    queues: frontier.FrontierQueues,
    walks: jax.Array,
    limits: jax.Array,
    key: jax.Array,
    pid: jax.Array,
    budget: jax.Array,
    *,
    spec: SamplingSpec,
    max_degree: int,
    flat_max_degree: int,
    depth: int,
    chunk: int,
    n_chunks: int,
    be: str,
    batched: bool,
    mode: str,
    buckets: tuple,
    use_chunked: bool,
    range_size: int,
    methods: tuple = (),
):
    """Drain up to ``budget`` entries of queue ``pid``: one ``lax.scan`` over
    ``n_chunks`` fixed-size chunks.  Each chunk pops, takes one walk step for
    all popped entries, scatters results into ``walks``, and redistributes
    survivors to their owning partitions' queues in one vectorized push.

    ``limits`` is the per-instance walk-length cap ``(I,)`` (the multi-request
    segment path: heterogeneous requests packed into one instance axis each
    stop at their own depth); ``depth`` stays the static bound that sizes
    ``walks`` and the scan."""
    dev = part.dev
    num_parts = queues.num_partitions
    program = tp.lower(spec)

    def _run_chunk(carry, kstep):
        queues, walks, sampled, budget_left = carry
        (v, inst, d, prev), taken, queues = frontier.pop_chunk(
            queues, pid, chunk, limit=budget_left, match_head_instance=not batched
        )
        # teleport-to-home epilogues read the walk's seed back off column 0
        home = walks[jnp.maximum(inst, 0), 0] if program.carries_home else None
        if mode == "flat":
            nxt = walk_flat_transition(
                kstep, dev.graph, dev.indices_global, part.flat_bias,
                part.padded, v, prev, jnp.zeros((), jnp.int32), spec, be,
                buckets=buckets, use_chunked=use_chunked,
                max_degree=flat_max_degree, row_of=dev.localize,
                program=program, home=home,
                methods=methods or None, tables=part.tables,
            )
        elif mode == "window":
            nxt = walk_window_transition(
                kstep, dev.graph, dev.indices_global, part.padded, v, prev,
                jnp.zeros((), jnp.int32), spec, program, be,
                buckets=buckets, use_chunked=use_chunked,
                max_degree=flat_max_degree, row_of=dev.localize, home=home,
            )
        else:
            ctx, mask = _edge_ctx(
                dev.graph, v, prev, jnp.zeros((), jnp.int32), max_degree,
                spec.needs_prev_neighbors, partition=dev,
            )
            nxt = walk_gather_transition(kstep, ctx, mask, spec, be, program, home)
        ok = (nxt >= 0) & (inst >= 0)
        # sentinel must be OOB-positive: mode="drop" WRAPS negative indices
        num_inst = walks.shape[0]
        walks = walks.at[jnp.where(ok, inst, num_inst), d + 1].set(nxt, mode="drop")
        sampled = sampled + jnp.sum(ok.astype(jnp.int32))
        cont = ok & (d + 1 < limits[jnp.maximum(inst, 0)])
        npid = pid_of_device(nxt, range_size, num_parts)
        queues = frontier.push_many(queues, npid, nxt, inst, d + 1, v, cont)
        return (queues, walks, sampled, budget_left - taken), taken

    def step(carry, t):
        # skip drained/over-budget chunks at runtime — the scan length is a
        # static worst case, but most calls see far fewer non-empty chunks
        has_work = (carry[0].count[pid] > 0) & (carry[3] > 0)
        return jax.lax.cond(
            has_work,
            _run_chunk,
            lambda c, _k: (c, jnp.zeros((), jnp.int32)),
            carry,
            jax.random.fold_in(key, t),
        )

    init = (queues, walks, jnp.zeros((), jnp.int32), jnp.int32(budget))
    (queues, walks, sampled, _), entries = jax.lax.scan(
        step, init, jnp.arange(n_chunks)
    )
    return queues, walks, sampled, entries, queues.count[pid]


def oom_random_walk(
    partitions: List[RangePartition],
    total_vertices: int,
    seeds: np.ndarray,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    memory_capacity: int = 2,
    num_streams: int = 2,
    chunk: int = 1024,
    batched: bool = True,
    workload_aware: bool = True,
    balance: bool = True,
    backend: bk.Backend = "auto",
    depth_limits: Optional[np.ndarray] = None,
    queue_capacity: Optional[int] = None,
    strict: bool = False,
) -> tuple[np.ndarray, OOMStats]:
    """Out-of-memory random walk over host-resident partitions.

    Returns (walks (I, depth+1), stats).  Flags map to the paper's ablations:
    ``batched`` = §V-C, ``workload_aware`` = §V-B scheduling, ``balance`` =
    thread-block workload balancing (proportional chunk budgets).
    ``backend`` picks the selection/walk kernels exactly as in the in-memory
    engines; ``"pallas"`` and ``"reference"`` produce bit-identical walks and
    stats (shared counted RNG, DESIGN.md §4/§8).

    ``depth_limits`` (optional ``(I,)``, values in ``[0, depth]``) is the
    multi-request segment path: the batched service (``repro.serve``) packs
    heterogeneous requests into one instance axis and each instance stops at
    its own limit, so one drain serves mixed walk lengths.  ``seeds`` may be
    ``-1`` (padding): those instances never enter a queue and emit all--1
    rows.

    ``queue_capacity`` overrides the per-partition frontier-queue capacity
    (default: sized to hold the whole instance population, which makes
    overflow impossible — every live instance has at most one queued entry).
    Capacity overflow on :func:`frontier.push_many` silently loses walkers
    (their rows freeze at the drop point); the count is always propagated to
    ``stats.frontier_dropped``, and ``strict=True`` turns a nonzero count
    into an immediate ``RuntimeError`` instead of a quietly short result.
    """
    num_parts = len(partitions)
    num_inst = len(seeds)
    pm = PartitionMap.create(total_vertices, num_parts)
    be = bk.resolve_backend(backend)
    program = tp.lower(spec)
    mode = program.mode
    # the bucketed paths plan from the TRUE max row degree (cheap to read
    # off the host-resident partitions): with an understated ``max_degree`` a
    # hub walker would match no bucket and silently die, where the gather
    # path merely truncates its neighborhood like the paper's padded gather
    flat_md = 1
    if mode != "opaque":
        for p in partitions:
            if p.num_vertices:
                flat_md = max(flat_md, int(np.diff(p.indptr).max()))
    if mode == "flat":
        buckets, use_chunked = bk.walk_bucket_plan(flat_md, exact=True)
    elif mode == "window":
        buckets, use_chunked = bk.walk_bucket_plan_window(flat_md)
    else:
        buckets, use_chunked = (), False

    seeds32 = jnp.asarray(np.asarray(seeds), jnp.int32)
    walks = jnp.full((num_inst, depth + 1), -1, jnp.int32).at[:, 0].set(seeds32)
    stats = OOMStats()
    if depth < 1 or num_inst == 0:
        return np.asarray(walks), stats
    if depth_limits is None:
        limits = jnp.full((num_inst,), depth, jnp.int32)
    else:
        limits_np = np.asarray(depth_limits, dtype=np.int32)
        if limits_np.shape != (num_inst,):
            raise ValueError(
                f"depth_limits shape {limits_np.shape} != (num_instances,) = ({num_inst},)"
            )
        if limits_np.size and (limits_np.min() < 0 or limits_np.max() > depth):
            # limits above `depth` would keep entries circulating through
            # the drain while every walks write past column `depth` is
            # silently dropped — wasted budget and inflated sampled_edges
            raise ValueError(
                f"depth_limits must lie in [0, depth={depth}], got "
                f"[{limits_np.min()}, {limits_np.max()}]"
            )
        limits = jnp.asarray(limits_np)

    cap = (
        int(queue_capacity)
        if queue_capacity is not None
        else -(-max(chunk, num_inst) // 128) * 128
    )
    if cap < 1:
        raise ValueError(f"queue_capacity must be >= 1, got {cap}")
    queues = frontier.make_queues(num_parts, cap)
    queues = frontier.push_many(
        queues,
        pm.pid_of_device(jnp.maximum(seeds32, 0)),
        seeds32,
        jnp.arange(num_inst, dtype=jnp.int32),
        jnp.zeros((num_inst,), jnp.int32),
        jnp.full((num_inst,), -1, jnp.int32),
        (seeds32 >= 0) & (limits > 0),
    )

    # pad every partition to one common shape => one drain trace serves all
    pad_v = pm.range_size
    pad_e = max(p.num_edges for p in partitions)

    # Adaptive selection planning (DESIGN.md §13): gather per-row bias stats
    # in a host pre-pass over the partition-LOCAL biases (non-resident
    # neighbors read degree 0 through the phantom row — §V semantics, so the
    # plan reflects what the drain will actually sample from), aggregate
    # them, and plan ONE methods tuple for all partitions — a per-partition
    # plan would fork the single shared drain trace.  Tables are built
    # lazily on first fetch and memoized by pid, so re-residencies after LRU
    # eviction never pay the O(E_P) alias build again.
    methods: tuple = ()
    fb_memo: dict[int, np.ndarray] = {}
    tables_memo: dict[int, mt.MethodTables] = {}
    if mode == "flat" and program.method != "its":
        n_cohorts = len(buckets) + (1 if use_chunked else 0)
        if program.method in ("alias", "rejection"):
            methods = (program.method,) * n_cohorts
        else:
            parts_stats = []
            for p in partitions:
                pdev = p.to_local_device_csr(pad_vertices=pad_v, pad_edges=pad_e)
                fb_np = np.maximum(
                    np.asarray(program.bias.fn(pdev.graph), dtype=np.float64), 0.0
                )
                fb_memo[p.pid] = fb_np
                ip = np.asarray(pdev.graph.indptr)
                deg = np.diff(ip).astype(np.int64)
                parts_stats.append((deg,) + mt.row_stats(ip, fb_np, deg))
            deg_all, mean_all, max_all, min_all = (
                np.concatenate(cols) for cols in zip(*parts_stats)
            )
            methods = mt.plan_methods(
                deg_all, (mean_all, max_all, min_all),
                buckets=buckets, use_chunked=use_chunked,
            )
        if mt.is_trivial(methods):
            methods = ()
            fb_memo.clear()

    def materialize(part: RangePartition) -> ResidentPartition:
        dev = part.to_local_device_csr(pad_vertices=pad_v, pad_edges=pad_e)
        if mode == "flat":
            fb = program.bias.fn(dev.graph)
            tables = mt.EMPTY_TABLES
            if methods:
                tables = tables_memo.get(part.pid)
                if tables is None:
                    fb_np = fb_memo.pop(part.pid, None)
                    if fb_np is None:  # forced override: no stats pre-pass ran
                        fb_np = np.maximum(np.asarray(fb, dtype=np.float64), 0.0)
                    ip = np.asarray(dev.graph.indptr)
                    prob = alias = row_max = None
                    if any(m == "alias" for m in methods):
                        pr, al = sel.build_alias(ip, fb_np)
                        prob, alias = jnp.asarray(pr), jnp.asarray(al)
                    if any(m == "rejection" for m in methods):
                        row_max = jnp.asarray(sel.build_row_max(ip, fb_np))
                    tables = mt.MethodTables(prob=prob, alias=alias, row_max=row_max)
                    tables_memo[part.pid] = tables
            return ResidentPartition(
                dev, fb, bk.pad_walk_csr(dev.indices_global, fb, buckets), tables
            )
        if mode == "window":
            # the dynamic hook reads edge weights off the gathered windows
            return ResidentPartition(
                dev, None, bk.pad_walk_csr(dev.indices_global, dev.graph.weights, buckets)
            )
        return ResidentPartition(dev, None, None)

    engine = TransferEngine(partitions, materialize, memory_capacity)
    # pop width caps at 256: frontier queues rarely hold a full `chunk` of
    # entries per partition, and denser, narrower steps beat wide padded
    # ones; the entry budget (num_streams * chunk) is preserved via n_chunks
    width = min(chunk, 256)
    drain = functools.partial(
        _drain,
        spec=spec, max_degree=max_degree, flat_max_degree=flat_md, depth=depth,
        chunk=width, n_chunks=-(-num_streams * chunk // width), be=be,
        batched=batched, mode=mode, buckets=buckets, use_chunked=use_chunked,
        range_size=pm.range_size, methods=methods,
    )

    call_idx = 0
    while True:
        counts = np.asarray(jax.device_get(queues.count))
        if counts.sum() == 0:
            break
        order, budgets = jax.device_get(
            _plan(queues.count, workload_aware=workload_aware, balance=balance,
                  num_streams=num_streams, chunk=chunk)
        )
        active = [(int(p), int(b)) for p, b in zip(order, budgets) if b > 0]
        for i, (pid, budget) in enumerate(active):
            part = engine.fetch(pid)
            processed = 0
            prefetched = False
            # paper: workload-aware sampling holds the partition until its
            # queue has no active vertices; the baseline releases it after
            # one budget's worth of entries.
            while True:
                call_idx += 1
                kcall = jax.random.fold_in(key, call_idx)
                left = budget if workload_aware else budget - processed
                queues, walks, sampled, entries, remaining = drain(
                    part, queues, walks, limits, kcall, jnp.int32(pid), jnp.int32(left)
                )
                if not prefetched and i + 1 < len(active):
                    # double buffering: the drain above is dispatched but not
                    # awaited — stage the next scheduled partition's transfer
                    # while the device computes
                    engine.prefetch(active[i + 1][0])
                    prefetched = True
                entries, sampled, remaining = jax.device_get(
                    (entries, sampled, remaining)
                )
                nonzero = [int(e) for e in entries if e > 0]
                stats.kernel_launches += len(nonzero)
                stats.entries_per_kernel.extend(nonzero)
                stats.sampled_edges += int(sampled)
                processed += int(entries.sum())
                if int(remaining) == 0 or not nonzero:
                    break
                if not workload_aware and processed >= budget:
                    break

    stats.partition_transfers = engine.stats_transfers
    stats.bytes_transferred = engine.stats_bytes
    stats.frontier_dropped = int(jax.device_get(queues.dropped))
    if strict and stats.frontier_dropped:
        raise RuntimeError(
            f"frontier queues dropped {stats.frontier_dropped} walker "
            f"entries to capacity overflow (queue_capacity={cap}, "
            f"{num_parts} partitions, {num_inst} instances): their walks "
            f"are silently truncated — raise queue_capacity or run with "
            f"strict=False to accept the counted loss"
        )
    return np.asarray(walks), stats
