"""Selection backend dispatcher: reference jnp vs compiled Pallas (DESIGN.md §6).

The engines never call the Pallas kernels directly — every ``select_*`` call
routes through this module, which owns the plumbing the kernels need:

- backend resolution: ``"auto"`` compiles through Mosaic on TPU and falls
  back to the pure-jnp reference path elsewhere (interpret-mode kernels are
  correct everywhere but only *fast* on TPU);
- lane-aligned padding of candidate pools to multiples of 128 (zero-bias pad
  candidates get zero-width CTPS regions, so results are unchanged);
- pre-generated counted-RNG retry budgets (:func:`repro.core.select.retry_randoms`)
  so the kernel's fixed ``ITERS`` unroll consumes bit-for-bit the same
  uniforms as the reference retry loop — ``backend="pallas"`` and
  ``backend="reference"`` agree exactly whenever the budget suffices;
- degree-bucketed walk scheduling (:func:`walk_step_bucketed`): per step,
  walkers are partitioned by degree into small/medium cohorts served by
  :func:`repro.kernels.walk_step.walk_step_pallas` with per-bucket
  ``max_seg`` windows, and a huge-degree cohort served by the chunked
  two-pass scan — the TPU analogue of the paper's workload-aware
  (KnightKing-style) scheduling.
"""
from __future__ import annotations

import logging
from typing import Literal, Mapping

import jax
import jax.numpy as jnp

from repro.core import select as sel
from repro.kernels import ref
from repro.kernels.alias_select import alias_step_pallas
from repro.kernels.its_select import its_select_pallas
from repro.kernels.walk_step import (
    _EPS,
    pad_csr_for_kernel,
    reject_step_pallas,
    walk_step_pallas,
    walk_step_window_pallas,
)

_logger = logging.getLogger(__name__)

Backend = Literal["auto", "reference", "pallas"]

#: candidate pools are padded to multiples of the TPU lane width
LANES = 128

#: default degree-bucket ladder for the walk fast path (DESIGN.md §6):
#: deg ∈ (0, 128] → small cohort, (128, 512] → medium cohort, > 512 → chunked
WALK_BUCKETS = (128, 512)

#: chunk width of the two-pass huge-degree scan
CHUNK = 512


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``"auto"`` → ``"pallas"`` on TPU, ``"reference"`` elsewhere."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend not in ("reference", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (use auto/reference/pallas)")
    return backend


def pad_lanes(biases: jax.Array) -> jax.Array:
    """Pad the candidate (last) dim to a lane multiple with zero bias."""
    p = biases.shape[-1]
    pad = (-p) % LANES
    if pad:
        biases = jnp.pad(biases, [(0, 0)] * (biases.ndim - 1) + [(0, pad)])
    return biases


def _masked(biases: jax.Array, mask: jax.Array | None) -> jax.Array:
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    if mask is not None:
        b = jnp.where(mask, b, 0.0)
    return b


def select_without_replacement(
    key: jax.Array,
    biases: jax.Array,
    mask: jax.Array | None,
    k: int,
    *,
    method: sel.SelectMethod = "its_brs",
    backend: Backend = "auto",
    max_iters: int = 32,
    blk_i: int = 8,
) -> sel.SelectResult:
    """Backend-dispatched without-replacement selection.

    ``its_brs`` has a fused Pallas kernel; ``gumbel`` is already TPU-native
    vector code and ``repeated``/``updated`` are diagnostic baselines, so all
    three run the reference implementation on every backend.  With the same
    ``max_iters`` the two backends agree bit-for-bit on indices, validity and
    the iteration/search counters (shared counted-RNG budget).
    """
    be = resolve_backend(backend)
    if be == "reference" or method != "its_brs":
        res = sel.select_without_replacement(key, biases, mask, k, method=method, max_iters=max_iters)
        if be == "pallas":
            # requested the kernel path but the method has no kernel: serve
            # from reference and SAY SO — the returned flag (and this
            # trace-time log) keep the adaptive auto-pick observable instead
            # of a silent substitution (DESIGN.md §13).
            _logger.debug(
                "select_without_replacement(method=%r) has no pallas kernel; "
                "serving backend=%r request from the reference path",
                method,
                backend,
            )
            res = res._replace(fell_back=True)
        return res

    b = _masked(biases, mask)
    batch_shape = b.shape[:-1]
    p = b.shape[-1]
    rands = sel.retry_randoms(key, batch_shape, max_iters, k)
    bf = pad_lanes(b.reshape(-1, p))
    rf = rands.reshape(-1, max_iters, k)
    idx, stats = its_select_pallas(bf, rf, blk_i=blk_i, with_stats=True)
    idx = idx.reshape(batch_shape + (k,))
    stats = stats.reshape(batch_shape + (2,))
    return sel.SelectResult(idx, idx >= 0, stats[..., 0], stats[..., 1])


def select_with_replacement(
    key: jax.Array,
    biases: jax.Array,
    mask: jax.Array | None,
    k: int,
    *,
    backend: Backend = "auto",
    blk_i: int = 8,
) -> jax.Array:
    """Backend-dispatched with-replacement ITS draw (random-walk case).

    Only ``k == 1`` has a kernel route (a single draw cannot self-collide, so
    the without-replacement kernel with a one-round budget computes exactly
    the with-replacement draw); larger ``k`` runs the reference path.
    Degenerate all-zero rows return ``P - 1`` like the reference (callers
    mask dead instances).
    """
    be = resolve_backend(backend)
    if be == "reference" or k != 1:
        return sel.select_with_replacement(key, biases, mask, k)
    b = _masked(biases, mask)
    batch_shape = b.shape[:-1]
    p = b.shape[-1]
    # same bits as the reference's uniform(key, batch + (1,)) draw
    r = jax.random.uniform(key, tuple(batch_shape) + (1, 1), dtype=jnp.float32)
    idx = its_select_pallas(pad_lanes(b.reshape(-1, p)), r.reshape(-1, 1, 1), blk_i=blk_i)
    idx = idx.reshape(batch_shape + (1,))
    return jnp.where(idx >= 0, idx, p - 1)


# ---------------------------------------------------------------------------
# Degree-bucketed walk scheduling (DESIGN.md §6)
# ---------------------------------------------------------------------------


def walk_bucket_plan(
    max_degree: int, segs: tuple = WALK_BUCKETS, exact: bool = False
) -> tuple[tuple, bool]:
    """Static per-graph schedule: kernel segment sizes + need for chunked tail.

    Returns ``(buckets, use_chunked)``: one :func:`walk_step_pallas` cohort
    per bucket segment, plus the two-pass chunked scan for degrees above the
    last segment.  Buckets the graph cannot populate are dropped at trace
    time.  With ``exact=True`` the caller asserts ``max_degree`` is the TRUE
    max row degree (not a possibly-understated padding bound), and the top
    segment shrinks to the smallest multiple of the previous bucket covering
    it (a graph with max degree 219 runs its top cohort in 256-wide windows,
    not 512-wide) — shrinking on an understated bound would leave real hub
    degrees with no cohort, silently killing their walkers.
    """
    buckets = []
    lo = 0
    for s in segs:
        if max_degree > lo:
            buckets.append(s)
        lo = s
    if not buckets:
        buckets = [segs[0]]
    if exact:
        base = buckets[-2] if len(buckets) > 1 else LANES
        fit = max(-(-max(max_degree, 1) // base) * base, LANES)
        buckets[-1] = min(buckets[-1], fit)
    return tuple(buckets), max_degree > segs[-1]


def pad_walk_csr(indices: jax.Array, flat_bias: jax.Array, buckets: tuple) -> dict:
    """Pre-pad flat CSR edge arrays once, shared by every bucket.

    One padding to the largest segment satisfies all smaller ones: the
    padded length is a multiple of every smaller ``seg`` (segments are
    powers-of-two multiples of 128) and the single spare ``buckets[-1]``
    block covers each cohort's ``blk+1`` window, so no per-bucket copies
    of the (E,) arrays are materialized.
    """
    big = max(buckets)
    padded = pad_csr_for_kernel(indices, flat_bias, big)
    assert all(big % seg == 0 for seg in buckets), buckets
    return {seg: padded for seg in buckets}


def walk_step_bucketed(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    flat_bias: jax.Array,
    padded: Mapping[int, tuple],
    cur: jax.Array,
    *,
    buckets: tuple,
    use_chunked: bool,
    interpret: bool | None = None,
    rand: jax.Array | None = None,
    tail_rand: jax.Array | None = None,
) -> jax.Array:
    """One bias-weighted transition for all walkers, scheduled by degree.

    ``flat_bias`` is the (E,) per-edge bias aligned with CSR order
    (``SamplingSpec.flat_edge_bias``); ``padded`` maps each bucket segment to
    its :func:`pad_csr_for_kernel` output.  Walkers outside a cohort run with
    ``deg = 0`` (a dead-end no-op) and take their result from their own
    cohort.  Returns next vertices (W,) int32; -1 for finished walkers and
    dead ends.  ``rand`` / ``tail_rand`` override the bucket / chunked-tail
    uniforms (the mesh-sharded drain supplies instance-indexed draws so a
    walker's pick matches the single-device stream wherever it runs,
    DESIGN.md §12); the default draws stay ``fold_in(key, 0)`` /
    ``fold_in(key, 1)``.
    """
    safe = jnp.maximum(cur, 0)
    starts = indptr[safe]
    deg = jnp.where(cur >= 0, indptr[safe + 1] - starts, 0)
    if rand is None:
        rand = jax.random.uniform(jax.random.fold_in(key, 0), cur.shape, dtype=jnp.float32)
    r = rand

    nxt = jnp.full_like(cur, -1)
    lo = 0
    for i, seg in enumerate(buckets):
        inds_p, bias_p = padded[seg]
        # understated max_degree degrades to NEIGHBORHOOD TRUNCATION (the
        # dense-gather contract), never silent walker death: without a
        # chunked tail the top cohort absorbs any larger degree, capped at
        # its window (same policy as the window scheduler below)
        absorb = i == len(buckets) - 1 and not use_chunked
        inb = (deg > lo) & ((deg <= seg) | absorb)
        cand = walk_step_pallas(
            jnp.where(inb, starts, 0),
            jnp.where(inb, jnp.minimum(deg, seg), 0),
            inds_p,
            bias_p,
            r,
            max_seg=seg,
            interpret=interpret,
        )
        nxt = jnp.where(inb, cand, nxt)
        lo = seg

    if use_chunked:
        nxt = _chunked_tail(
            jax.random.fold_in(key, 1), indptr, indices, flat_bias, safe, deg, buckets[-1], nxt,
            rand=tail_rand,
        )
    return nxt


def _chunked_tail(key, indptr, indices, flat_bias, safe, deg, seg_hi, nxt, rand=None):
    """Route walkers with ``deg > seg_hi`` through the two-pass chunked scan."""
    huge = deg > seg_hi
    safe_cur = jnp.where(huge, safe, 0)
    off = sel.walk_transition_chunked(key, indptr, flat_bias, safe_cur, chunk=CHUNK, rand=rand)
    eidx = jnp.clip(indptr[safe_cur] + jnp.maximum(off, 0), 0, indices.shape[0] - 1)
    cand = jnp.where(off >= 0, indices[eidx], -1)
    return jnp.where(huge, cand, nxt)


def walk_step_flat_reference(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    flat_bias: jax.Array,
    padded: Mapping[int, tuple],
    cur: jax.Array,
    *,
    buckets: tuple,
    use_chunked: bool,
    max_degree: int | None = None,
    rand: jax.Array | None = None,
    tail_rand: jax.Array | None = None,
) -> jax.Array:
    """Pure-jnp mirror of :func:`walk_step_bucketed` — same bits, same picks.

    Replays the kernel's exact arithmetic (block-aligned window at the
    walker's ``start % seg`` offset, masked cumsum, count-crossings pick) on
    the SAME padded edge arrays and the SAME ``fold_in(key, 0)`` /
    ``fold_in(key, 1)`` uniforms, so the §V drain loop gets bit-identical
    walks from ``backend="reference"`` and ``backend="pallas"`` while the
    reference path stays kernel-free.  XLA's cumsum is position-indexed
    (prefix ``i`` combines elements in a tree fixed by ``i`` alone), so
    elements must sit at the kernel's window offsets — but the window TAIL
    may be truncated: when ``max_degree`` is given the window shrinks from
    ``2*seg`` to ``seg + min(seg, max_degree)`` without changing any prefix.
    The selected id is gathered directly instead of through the kernel's
    float32 one-hot reduction (identical for ids < 2^24, i.e. any graph this
    repo can hold in f32 bias arrays).
    """
    safe = jnp.maximum(cur, 0)
    starts = indptr[safe]
    deg = jnp.where(cur >= 0, indptr[safe + 1] - starts, 0)
    if rand is None:
        rand = jax.random.uniform(jax.random.fold_in(key, 0), cur.shape, dtype=jnp.float32)
    r = rand

    nxt = jnp.full_like(cur, -1)
    lo = 0
    for i, seg in enumerate(buckets):
        inds_p, bias_p = padded[seg]
        # same truncation-absorb policy as walk_step_bucketed — the two must
        # mirror each other bit-for-bit
        absorb = i == len(buckets) - 1 and not use_chunked
        inb = (deg > lo) & ((deg <= seg) | absorb)
        width = 2 * seg if max_degree is None else seg + min(seg, max_degree)
        cand = ref.walk_step_block_ref(
            jnp.where(inb, starts, 0), jnp.where(inb, jnp.minimum(deg, seg), 0),
            inds_p, bias_p, r, seg=seg, width=width,
        )
        nxt = jnp.where(inb, cand, nxt)
        lo = seg

    if use_chunked:
        nxt = _chunked_tail(
            jax.random.fold_in(key, 1), indptr, indices, flat_bias, safe, deg, buckets[-1], nxt,
            rand=tail_rand,
        )
    return nxt


# ---------------------------------------------------------------------------
# Adaptive per-bucket method dispatch (DESIGN.md §13)
# ---------------------------------------------------------------------------


def walk_step_adaptive(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    flat_bias: jax.Array,
    padded: Mapping[int, tuple],
    cur: jax.Array,
    *,
    buckets: tuple,
    use_chunked: bool,
    methods: tuple,
    tables,
    backend: str,
    max_degree: int | None = None,
    interpret: bool | None = None,
    rand: jax.Array | None = None,
    tail_rand: jax.Array | None = None,
    rej_rand: jax.Array | None = None,
) -> jax.Array:
    """One flat-bias transition with a per-cohort selection method.

    The adaptive generalization of :func:`walk_step_bucketed` /
    :func:`walk_step_flat_reference`: ``methods`` (static, from
    ``core.methods.plan_methods``) names the draw each degree cohort runs —
    ``"its"`` (the legacy cumsum kernel/mirror), ``"alias"`` (O(1) draw from
    ``tables.prob``/``tables.alias``), or ``"rejection"`` (counted-budget
    envelope test against ``tables.row_max``) — one entry per bucket plus
    one for the chunked tail when present.  ONE function serves both
    backends: alias and rejection cohorts dispatch a Pallas kernel under
    ``backend="pallas"`` and the bit-identical pure-jnp flat draws under
    ``"reference"``; ITS cohorts keep the existing kernel/mirror pair.

    Counted RNG (all cohorts, both backends): the single bucket uniform is
    ``fold_in(key, 0)`` — alias draws consume the SAME uniform an ITS cohort
    would, so each walker's stream is method-independent plumbing-wise; the
    ITS/alias tail uses ``fold_in(key, 1)``; the rejection budget (shared by
    every rejection cohort including the tail — each walker lives in exactly
    one cohort) is ``rejection_randoms(fold_in(key, 2))``, generated only
    when some cohort rejects.  ``rand`` / ``tail_rand`` / ``rej_rand``
    override the draws (the mesh-sharded drain supplies instance-indexed
    streams, DESIGN.md §12).

    O(1) methods have no O(degree) window constraint, so alias/rejection
    TAILS draw over the full row via the shared flat-gather helpers —
    removing the two-pass chunked scan from hub vertices entirely; only an
    ITS tail still scans.
    """
    safe = jnp.maximum(cur, 0)
    starts = indptr[safe]
    deg = jnp.where(cur >= 0, indptr[safe + 1] - starts, 0)
    if rand is None:
        rand = jax.random.uniform(jax.random.fold_in(key, 0), cur.shape, dtype=jnp.float32)
    r = rand
    if any(m == "rejection" for m in methods) and rej_rand is None:
        rej_rand = sel.rejection_randoms(jax.random.fold_in(key, 2), cur.shape)
    rmv = None
    if tables.row_max is not None:
        rmv = jnp.where(cur >= 0, tables.row_max[safe], 0.0)
    pal = backend == "pallas"
    tables_p = None
    if pal and any(m == "alias" for m in methods):
        # one padding to the largest segment serves every alias cohort (the
        # same geometry argument as pad_walk_csr); pad values are never read
        # for real rows
        a_pad, p_pad = pad_csr_for_kernel(tables.alias, tables.prob, max(buckets))
        tables_p = (p_pad, a_pad)

    nxt = jnp.full_like(cur, -1)
    lo = 0
    for i, seg in enumerate(buckets):
        inds_p, bias_p = padded[seg]
        # same truncation-absorb policy as walk_step_bucketed: an understated
        # max_degree degrades to neighborhood truncation (cap = seg inside
        # each draw), never silent walker death
        absorb = i == len(buckets) - 1 and not use_chunked
        inb = (deg > lo) & ((deg <= seg) | absorb)
        st = jnp.where(inb, starts, 0)
        dg = jnp.where(inb, deg, 0)
        m = methods[i]
        if m == "alias":
            if pal:
                cand = alias_step_pallas(
                    st, dg, inds_p, tables_p[0], tables_p[1], r,
                    max_seg=seg, interpret=interpret,
                )
            else:
                cand = sel.alias_draw_flat(
                    st, dg, tables.prob, tables.alias, indices, r, cap=seg
                )
        elif m == "rejection":
            if pal:
                cand = reject_step_pallas(
                    st, dg, inds_p, bias_p, rmv, rej_rand,
                    max_seg=seg, interpret=interpret,
                )
            else:
                cand = sel.rejection_draw_flat(
                    st, dg, flat_bias, rmv, indices, rej_rand, cap=seg
                )
        elif pal:
            cand = walk_step_pallas(
                st, jnp.minimum(dg, seg), inds_p, bias_p, r,
                max_seg=seg, interpret=interpret,
            )
        else:
            width = 2 * seg if max_degree is None else seg + min(seg, max_degree)
            cand = ref.walk_step_block_ref(
                st, jnp.minimum(dg, seg), inds_p, bias_p, r, seg=seg, width=width
            )
        nxt = jnp.where(inb, cand, nxt)
        lo = seg

    if use_chunked:
        huge = deg > buckets[-1]
        st = jnp.where(huge, starts, 0)
        dg = jnp.where(huge, deg, 0)
        mt = methods[len(buckets)]
        if mt == "alias":
            if tail_rand is None:
                tail_rand = jax.random.uniform(
                    jax.random.fold_in(key, 1), cur.shape, dtype=jnp.float32
                )
            cand = sel.alias_draw_flat(
                st, dg, tables.prob, tables.alias, indices, tail_rand
            )
            nxt = jnp.where(huge, cand, nxt)
        elif mt == "rejection":
            cand = sel.rejection_draw_flat(st, dg, flat_bias, rmv, indices, rej_rand)
            nxt = jnp.where(huge, cand, nxt)
        else:
            nxt = _chunked_tail(
                jax.random.fold_in(key, 1), indptr, indices, flat_bias, safe, deg,
                buckets[-1], nxt, rand=tail_rand,
            )
    return nxt


# ---------------------------------------------------------------------------
# Degree-bucketed WINDOW-bias walk scheduling (transition programs, §10)
# ---------------------------------------------------------------------------


def walk_bucket_plan_window(max_degree: int, segs: tuple = WALK_BUCKETS) -> tuple[tuple, bool]:
    """Bucket plan for the window-bias path: exact, and ladder-merged.

    Window biases are *evaluated* per cohort, so every extra bucket re-runs
    the dynamic hook (and its prev-membership search) over all walkers at
    that cohort's width — a small bucket only pays for itself when the top
    segment is much wider.  Plan exactly (the window path treats
    ``max_degree`` as the true max row degree, like the OOM drain), then
    collapse the ladder into the top cohort when it is at most twice the
    bottom one.  Degrees above the top segment take the chunked dynamic
    tail.
    """
    buckets, use_chunked = walk_bucket_plan(max_degree, segs, exact=True)
    if len(buckets) > 1 and buckets[-1] <= 2 * buckets[0]:
        buckets = buckets[-1:]
    return tuple(buckets), use_chunked


def walk_step_bucketed_window(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    padded: Mapping[int, tuple],
    cur: jax.Array,
    bias_of,
    *,
    buckets: tuple,
    use_chunked: bool,
    backend: str,
    interpret: bool | None = None,
    rand: jax.Array | None = None,
    tail_rand: jax.Array | None = None,
) -> jax.Array:
    """One dynamic-bias transition for all walkers, scheduled by degree.

    The ``WindowBias`` analogue of :func:`walk_step_bucketed` /
    :func:`walk_step_flat_reference` — ONE function serves both backends
    because the expensive, semantics-bearing part (evaluating the dynamic
    edge-bias hook) runs in shared jnp either way:

    per bucket, each walker's *compact* ``(W, seg)`` row window is gathered
    from the padded CSR arrays (``padded[seg] = (ids, weights)``,
    :func:`pad_walk_csr` over edge WEIGHTS, not a flat bias) and
    ``bias_of(u, w, mask, eidx) -> biases`` is evaluated on it — the narrowest
    arrays the hook (and its prev-membership search) can see.  The computed
    bias is then re-aligned into the kernel's block-aligned ``(W, 2·seg)``
    window (one cheap row-local gather; per-edge bias values are unchanged)
    and the ITS pick runs
    :func:`~repro.kernels.walk_step.walk_step_window_pallas` under
    ``backend="pallas"`` or the bit-identical
    :func:`~repro.kernels.ref.walk_step_window_block_ref` mirror under
    ``"reference"`` — same bias rows, same uniforms, same picks.

    Degrees above the last bucket take the two-pass chunked scan
    (:func:`~repro.core.select.walk_transition_chunked_window`), evaluating
    the hook chunk-by-chunk — no ``(W, max_degree)`` tensor exists on any
    path.  Returns next vertices (W,) int32; -1 for finished walkers and
    dead ends.
    """
    safe = jnp.maximum(cur, 0)
    starts = indptr[safe]
    deg = jnp.where(cur >= 0, indptr[safe + 1] - starts, 0)
    if rand is None:
        rand = jax.random.uniform(jax.random.fold_in(key, 0), cur.shape, dtype=jnp.float32)
    r = rand

    nxt = jnp.full_like(cur, -1)
    lo = 0
    for i, seg in enumerate(buckets):
        inds_p, wts_p = padded[seg]
        # an understated max_degree (possible in-memory, where the caller's
        # bound is trusted for the exact bucket plan) degrades to
        # NEIGHBORHOOD TRUNCATION — the dense-gather path's contract — never
        # silent walker death: without a chunked tail the top cohort absorbs
        # any larger degree, capped at its window
        absorb = i == len(buckets) - 1 and not use_chunked
        inb = (deg > lo) & ((deg <= seg) | absorb)
        st = jnp.where(inb, starts, 0)
        dg = jnp.where(inb, jnp.minimum(deg, seg), 0)
        # compact row-aligned windows for the hook (row fits: dg <= seg, and
        # the padded arrays keep a spare trailing block, so st+seg is safe)
        offs_c = jnp.arange(seg, dtype=jnp.int32)
        cmask = offs_c < dg[..., None]
        ceidx = st[..., None] + offs_c
        u_c = jnp.where(cmask, inds_p[ceidx], -1)
        w_c = jnp.where(cmask, wts_p[ceidx], 0.0)
        # the hook also receives the window's edge positions (``ceidx``) so
        # per-edge side lanes (the sharded drain's replicated degree lane)
        # can be gathered without row lookups; in-memory hooks ignore it
        bias_c = jnp.where(cmask, jnp.maximum(bias_of(u_c, w_c, cmask, ceidx), 0.0), 0.0)
        # re-align to the kernel's 2-block window at offset start % seg
        # (same geometry the reference pick uses — shared helper keeps the
        # bit-parity contract in one place)
        local, _, offs, mask = ref._block_window(st, dg, seg, 2 * seg)
        src = jnp.clip(offs - local[..., None], 0, seg - 1)
        bias_win = jnp.where(mask, jnp.take_along_axis(bias_c, src, axis=-1), 0.0)
        if backend == "pallas":
            cand = walk_step_window_pallas(
                st, dg, inds_p, bias_win, r, max_seg=seg, interpret=interpret
            )
        else:
            cand = ref.walk_step_window_block_ref(st, dg, inds_p, bias_win, r, seg=seg)
        nxt = jnp.where(inb, cand, nxt)
        lo = seg

    if use_chunked:
        huge = deg > buckets[-1]
        safe_cur = jnp.where(huge, safe, 0)
        off = sel.walk_transition_chunked_window(
            jax.random.fold_in(key, 1), indptr, indices, weights, safe_cur, bias_of,
            chunk=CHUNK, rand=tail_rand,
        )
        eidx = jnp.clip(indptr[safe_cur] + jnp.maximum(off, 0), 0, indices.shape[0] - 1)
        cand = jnp.where(off >= 0, indices[eidx], -1)
        nxt = jnp.where(huge, cand, nxt)
    return nxt
