"""Serving benchmark: fused multi-request batching → BENCH_serve.json.

Measures what the batched multi-instance sampling service (``repro.serve``)
buys over one-launch-per-request serving: 64 concurrent requests are
submitted and drained through (a) fused padding-bucket cohorts and (b) the
bit-identical ``ServiceConfig(fuse=False)`` baseline, across three
request-arrival mixes on the pl50k benchmark graph (reference backend —
the cross-host number; the kernel path only changes what runs inside each
launch, not how many launches there are):

- ``uniform``        — one algorithm, one walk length, one request size;
- ``skewed_lengths`` — same algorithm, power-law-skewed walk lengths
  (depth buckets fragment the cohorts; the realistic arrival case);
- ``mixed_specs``    — node2vec (1 in 4) / deepwalk / weighted mix with
  mixed lengths (cohorts also split per lowered transition program).

Headline: fused-vs-sequential speedup per mix, plus requests/s and
walker-steps/s throughput.  Acceptance floor (ISSUE 4): >= 1.5x on the
mixed-spec mix.

The **open-loop** section (ISSUE 10) measures the always-on
:class:`~repro.serve.StreamingSamplingService` under Poisson arrivals: a
pre-sampled request population (mixed specs, tiered priorities/deadlines)
is submitted on an open-loop schedule — arrival times fixed in advance, so
a slow server cannot slow the offered load — at three rates spanning
under- to near-saturation of a launch-per-request server (the capacity
proxy is one measured single-request launch).  Each rate runs twice over
the identical population and schedule: continuous batching
(``StreamConfig(batching=True)``) vs the launch-per-request baseline
(``batching=False`` — same scheduler, no co-batching), reporting per-tier
p50/p99 total latency and sustained requests/s.  Acceptance: batching
beats the baseline on p99 at the highest rate, zero requests dropped.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--iters 3]
        [--open-loop-only] [--open-loop-n 150]
(also exposed as ``run()`` rows through benchmarks/run.py)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import BENCH_GRAPHS, row, timeit  # noqa: E402

from repro.core import algorithms as alg  # noqa: E402
from repro.core.engine import random_walk_segments  # noqa: E402
from repro.serve import (  # noqa: E402
    AdmissionError,
    Priority,
    SamplingService,
    ServiceConfig,
    StreamConfig,
    StreamingSamplingService,
)
from repro.serve.stream import percentile  # noqa: E402

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

GRAPH = "pl50k"
N_REQUESTS = 64

# open-loop serving geometry: one width/depth bucket so the streamed
# cohorts reuse a handful of prewarmed traces (sizes 9..16 -> bucket 16)
OPEN_LOOP_N = 150
OL_DEPTH = 8
OL_WIDTH = 16
OL_MAX_COHORT = 16
OL_WINDOW_MS = 10.0
TIER_NAMES = {0: "interactive", 1: "standard", 2: "bulk"}


def _request_mixes(g, rng):
    """64-request arrival mixes; every request carries an explicit key so the
    fused and sequential services serve literally identical work."""
    n2v = alg.node2vec()  # ONE spec instance: its requests may fuse
    mixes = {}

    # serving-scale requests: a user asks for a handful of walks.  This is
    # the regime batching is FOR — each standalone launch is fixed-overhead
    # dominated, so cohorts amortize it across requests.
    uniform = []
    for i in range(N_REQUESTS):
        uniform.append((alg.deepwalk(), rng.integers(0, g.num_vertices, 16), 16))
    mixes["uniform"] = uniform

    skewed = []
    depths = rng.choice([4, 8, 16, 32, 64], size=N_REQUESTS, p=[0.35, 0.3, 0.2, 0.1, 0.05])
    for i in range(N_REQUESTS):
        skewed.append((alg.deepwalk(), rng.integers(0, g.num_vertices, 16), int(depths[i])))
    mixes["skewed_lengths"] = skewed

    mixed = []
    specs = [alg.deepwalk(), n2v, alg.weighted_random_walk(), alg.deepwalk()]
    for i in range(N_REQUESTS):
        spec = specs[i % len(specs)]
        n = int(rng.integers(9, 17))  # one width bucket, varying fill
        depth = int(rng.choice([8, 16]))
        mixed.append((spec, rng.integers(0, g.num_vertices, n), depth))
    mixes["mixed_specs"] = mixed
    return mixes


def _serve_once(svc, requests, keys):
    for (spec, seeds, depth), key in zip(requests, keys):
        svc.submit(seeds, depth=depth, spec=spec, key=key)
    results = svc.drain()
    assert len(results) == len(requests)
    return results


def _bench_mode(g, requests, keys, fuse, iters):
    """Median submit+drain wall seconds in steady state (post-compile)."""
    mk = lambda: SamplingService(  # noqa: E731
        g, backend="reference", config=ServiceConfig(fuse=fuse)
    )
    svc = mk()
    _serve_once(svc, requests, keys)  # warmup: compile every cohort trace
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _serve_once(svc, requests, keys)
        times.append(time.perf_counter() - t0)
    times.sort()
    stats = svc.stats
    return times[len(times) // 2], stats


# ---------------------------------------------------------------------------
# Open-loop streaming load harness (ISSUE 10)
# ---------------------------------------------------------------------------


def _open_loop_population(g, rng, n):
    """Pre-sampled request population: mixed specs within one padding
    bucket, tiered priorities/deadlines (1-in-4 interactive at 50 ms,
    1-in-4 bulk at 500 ms, the rest window-bound standard), explicit keys —
    every leg and both modes serve literally identical work."""
    specs = [alg.deepwalk(), alg.weighted_random_walk()]
    base = jax.random.PRNGKey(23)
    pop = []
    for i in range(n):
        if i % 4 == 0:
            tier, deadline = Priority.INTERACTIVE, 50.0
        elif i % 4 == 2:
            tier, deadline = Priority.BULK, 500.0
        else:
            tier, deadline = Priority.STANDARD, None
        pop.append((
            specs[i % 2],
            rng.integers(0, g.num_vertices, int(rng.integers(9, OL_WIDTH + 1))),
            tier, deadline, jax.random.fold_in(base, i),
        ))
    return pop


def _ol_service(g):
    """A streaming-ready service: generous back-pressure ceilings (the har-
    ness asserts zero drops) and every cohort shape prewarmed — the fused
    trace keys on the pow2-bucketed request axis, so warm each size the
    scheduler can form up to ``max_requests_per_launch``."""
    svc = SamplingService(
        g, backend="reference", key=jax.random.PRNGKey(3),
        config=ServiceConfig(
            max_pending_requests=1 << 15, max_pending_walkers=1 << 22,
            max_requests_per_launch=OL_MAX_COHORT,
        ),
    )
    for spec in (alg.deepwalk(), alg.weighted_random_walk()):
        r = 1
        while r <= OL_MAX_COHORT:
            svc.prewarm(spec, depth=OL_DEPTH, width=OL_WIDTH, requests=r)
            r *= 2
    return svc


def _single_launch_ms(g):
    """Measured cost of one single-request launch at the serving geometry —
    the capacity proxy the open-loop rates are set against."""
    seeds = np.full((1, OL_WIDTH), -1, np.int32)
    seeds[0, :12] = np.arange(12)
    keys = jnp.stack([jax.random.PRNGKey(0)])
    md = int(g.max_degree())
    fn = lambda: random_walk_segments(  # noqa: E731
        g, jnp.asarray(seeds), keys, depth=OL_DEPTH, spec=alg.deepwalk(),
        max_degree=md, backend="reference",
    )
    return timeit(fn, warmup=1, iters=5) * 1e3


def _run_open_loop_leg(g, pop, rate, batching, seed):
    """One open-loop run: Poisson arrivals at ``rate`` req/s over ``pop``."""
    svc = _ol_service(g)
    stream_cfg = StreamConfig(max_batch_window_ms=OL_WINDOW_MS, batching=batching)
    arrivals = np.cumsum(np.random.default_rng(seed).exponential(1.0 / rate, len(pop)))
    futs, dropped = [], 0
    with StreamingSamplingService(svc, stream_cfg) as stream:
        t0 = time.perf_counter()
        for (spec, seeds, tier, deadline, key), at in zip(pop, arrivals):
            delay = t0 + at - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                futs.append(stream.submit(
                    seeds, depth=OL_DEPTH, spec=spec, key=key,
                    deadline_ms=deadline, priority=tier,
                ))
            except AdmissionError:
                dropped += 1
        for f in futs:
            f.result(timeout=600)
        t1 = time.perf_counter()
    lats = [f.latency for f in futs]
    total = [l.total_ms for l in lats]
    tiers = {}
    for tval, tname in TIER_NAMES.items():
        tl = [l.total_ms for l in lats if l.tier == tval]
        if tl:
            tiers[tname] = {
                "n": len(tl),
                "p50_ms": percentile(tl, 50),
                "p99_ms": percentile(tl, 99),
            }
    return {
        "mode": "batching" if batching else "per_request",
        "offered_rps": rate,
        "n_requests": len(pop),
        "completed": len(futs),
        "dropped": dropped,
        "sustained_rps": len(futs) / (t1 - t0),
        "launches": svc.stats.stream_launches,
        "deadline_misses": svc.stats.stream_deadline_misses,
        "p50_ms": percentile(total, 50),
        "p99_ms": percentile(total, 99),
        "tiers": tiers,
    }


def _open_loop_section(g, n):
    """The open-loop sweep: 3 rates x {per_request, batching} over one
    population; returns (section dict, CSV rows)."""
    pop = _open_loop_population(g, np.random.default_rng(29), n)
    single_ms = _single_launch_ms(g)
    cap = 1e3 / single_ms  # req/s a launch-per-request server could sustain
    legs, rows = [], []
    for frac in (0.25, 0.6, 1.0):
        rate = frac * cap
        for batching in (False, True):
            leg = _run_open_loop_leg(g, pop, rate, batching, seed=int(frac * 100))
            leg["offered_fraction_of_capacity"] = frac
            legs.append(leg)
            rows.append(row(
                f"serve_openloop_{leg['mode']}_r{int(round(rate))}",
                leg["p99_ms"] * 1e3,
                f"p50={leg['p50_ms']:.1f}ms;p99={leg['p99_ms']:.1f}ms;"
                f"rps={leg['sustained_rps']:.0f};launches={leg['launches']};"
                f"dropped={leg['dropped']}",
            ))
    section = {
        "graph": GRAPH,
        "n_requests_per_leg": n,
        "depth": OL_DEPTH,
        "window_ms": OL_WINDOW_MS,
        "single_launch_ms": single_ms,
        "capacity_proxy_rps": cap,
        "legs": legs,
    }
    return section, rows


def run(iters: int = 3, open_loop_n: int = OPEN_LOOP_N,
        closed_loop: bool = True, open_loop: bool = True):
    g = BENCH_GRAPHS[GRAPH]()
    rng = np.random.default_rng(17)
    mixes = _request_mixes(g, rng) if closed_loop else {}
    base_key = jax.random.PRNGKey(9)
    results = []
    for mix_name, requests in mixes.items():
        keys = [jax.random.fold_in(base_key, i) for i in range(len(requests))]
        walker_steps = sum(len(s) * d for _, s, d in requests)
        fused_s, fstats = _bench_mode(g, requests, keys, fuse=True, iters=iters)
        seq_s, _ = _bench_mode(g, requests, keys, fuse=False, iters=iters)
        launches_per_drain = fstats.launches // (iters + 1)
        entry = {
            "graph": GRAPH,
            "mix": mix_name,
            "n_requests": len(requests),
            "walker_steps": walker_steps,
            "fused_seconds": fused_s,
            "sequential_seconds": seq_s,
            "speedup": seq_s / fused_s,
            "fused_launches_per_drain": launches_per_drain,
            "fused_requests_per_s": len(requests) / fused_s,
            "fused_walker_steps_per_s": walker_steps / fused_s,
            "sequential_walker_steps_per_s": walker_steps / seq_s,
        }
        results.append(entry)
        yield row(
            f"serve_{mix_name}_fused", fused_s * 1e6,
            f"requests={len(requests)};launches={launches_per_drain};"
            f"speedup={entry['speedup']:.2f}x",
        )
        yield row(f"serve_{mix_name}_sequential", seq_s * 1e6,
                  f"requests={len(requests)};launches={len(requests)}")

    payload = {
        # shared benchmark-JSON schema (DESIGN.md §9): diffable PR-over-PR
        "bench": "serve",
        "device": jax.default_backend(),
        "backend": "reference",
        "graph": GRAPH,
        "n_requests": N_REQUESTS,
        "results": results,
    }
    if open_loop:
        section, ol_rows = _open_loop_section(g, open_loop_n)
        payload["open_loop"] = section
        for r in ol_rows:
            yield r
    OUT_PATH.write_text(json.dumps(payload, indent=2))
    yield row("serve_json", 0.0, str(OUT_PATH.name))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--open-loop-n", type=int, default=OPEN_LOOP_N,
                    help="requests per open-loop leg")
    ap.add_argument("--open-loop-only", action="store_true",
                    help="skip the closed-loop fused-vs-sequential section "
                         "(CI smoke)")
    ap.add_argument("--no-open-loop", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(args.iters, open_loop_n=args.open_loop_n,
                 closed_loop=not args.open_loop_only,
                 open_loop=not args.no_open_loop):
        print(r, flush=True)


if __name__ == "__main__":
    main()
