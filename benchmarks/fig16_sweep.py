"""Paper Fig. 16: NeighborSize and #instances sweeps (biased neighbor
sampling, Depth=3)."""
from __future__ import annotations

import jax

from benchmarks.common import BENCH_GRAPHS, row, timeit
from repro.core import algorithms as alg
from repro.core.engine import traversal_sample


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(3)
    g = BENCH_GRAPHS["pl50k"]()
    md = min(g.max_degree(), 512)

    for ns in (1, 2, 4, 8):
        spec = alg.biased_neighbor_sampling(neighbor_size=ns, frontier_size=4)
        pools = jax.random.randint(key, (2048, 1), 0, g.num_vertices)

        def go():
            return traversal_sample(g, pools, key, depth=3, spec=spec,
                                    max_degree=md, pool_capacity=256,
                                    max_vertices=g.num_vertices)

        secs = timeit(go)
        edges = int(go().num_edges.sum())
        rows.append(row(f"fig16a/NS={ns}", secs * 1e6, f"SEPS={edges/secs:.3e}"))

    spec = alg.biased_neighbor_sampling(neighbor_size=8, frontier_size=4)
    for n_inst in (2000, 4000, 8000, 16000):
        pools = jax.random.randint(key, (n_inst, 1), 0, g.num_vertices)

        def go():
            return traversal_sample(g, pools, key, depth=3, spec=spec,
                                    max_degree=md, pool_capacity=256,
                                    max_vertices=g.num_vertices)

        secs = timeit(go)
        edges = int(go().num_edges.sum())
        rows.append(row(f"fig16b/inst={n_inst}", secs * 1e6, f"SEPS={edges/secs:.3e}"))
    return rows
