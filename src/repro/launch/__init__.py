"""launch subpackage."""
