"""Batched multi-instance sampling service.

C-SAW's out-of-memory design rests on batched multi-instance sampling —
packing many concurrent sampling instances into one device pass to amortize
transfers (paper §V-C).  This module lifts that idea one level up, to
*independent user requests*: a :class:`SamplingService` accepts many
concurrent, heterogeneous requests (different seed sets, walk lengths,
:class:`~repro.core.api.SamplingSpec`\\ s), fuses the compatible ones into
shared device launches, and unpacks per-request results.

The pipeline per :meth:`SamplingService.drain`:

1. :class:`~repro.serve.queue.RequestQueue` groups pending requests into
   padding-bucket **cohorts** keyed on the lowered transition program
   (``queue.cohort_key``) — one compiled trace per cohort shape.
2. Each cohort's seed sets are packed into one ``(R, W)`` matrix (one row
   per request, ``-1``-padded to the width bucket) with stacked per-request
   PRNG keys, and run through ``engine.random_walk_segments`` — a single
   fused launch whose row ``r`` is bit-identical to the standalone
   ``random_walk(graph, padded_seeds_r, key_r, depth=bucket)`` call on
   either backend.
3. When the service holds a *partitioned* graph instead of an in-memory
   one, the cohort routes to the §V frontier-queue drain
   (``oom_random_walk``): all member requests merge into one flat instance
   axis with per-instance ``depth_limits``, so one partition-scheduling
   pass serves every request in the cohort.
4. Results are sliced back per request: row padding off, depth bucket
   truncated to the request's own walk length.

Because fusing is a pure batching transform, ``ServiceConfig(fuse=False)``
(one launch per request, same padding) returns bit-identical responses —
that invariance is tested, and the throughput gap between the two modes is
the service's reason to exist (``benchmarks/bench_serve.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.api import SamplingSpec
from repro.core import backend as bk
from repro.core import transition as tp
from repro.core.engine import flat_method_plan, random_walk, random_walk_segments
from repro.core.oom import oom_random_walk
from repro.graph.csr import CSRGraph
from repro.graph.partition import RangePartition
from repro.shard.walk import sharded_random_walk
from repro.serve.queue import (
    AdmissionError,
    Cohort,
    RequestQueue,
    SamplingRequest,
    ServiceConfig,
    _pow2_bucket,
)


class DrainError(RuntimeError):
    """A cohort launch failed mid-drain.

    No request is lost: the failing cohort's and all not-yet-served
    requests are re-queued (same ids — ``drain()`` again to retry), and
    results of cohorts that completed before the failure are on
    ``completed``.
    """

    def __init__(self, message: str, completed: "Dict[int, RequestResult]"):
        super().__init__(message)
        self.completed = completed


class RequestResult(NamedTuple):
    """Per-request response: exactly the requested geometry, padding gone."""

    request_id: int
    walks: np.ndarray  # (n, depth+1) int32, -1 after termination
    lengths: np.ndarray  # (n,) realized lengths (# vertices)
    sampled_edges: int  # total edges this request sampled


class RequestLatency(NamedTuple):
    """One streamed request's life-cycle timing (``serve.stream``).

    ``queue_ms`` is submission → launch start (the batching-window cost),
    ``launch_ms`` the request's cohort launch wall time, ``total_ms``
    submission → result delivery.  ``deadline_met`` is ``None`` for
    requests submitted without a deadline.
    """

    request_id: int
    tier: int  # Priority value (lower = more urgent)
    queue_ms: float
    launch_ms: float
    total_ms: float
    reason: str  # what launched the cohort: fill / slack / window / flush / immediate
    deadline_met: Optional[bool]


@dataclasses.dataclass
class ServiceStats:
    """Serving counters since construction (the benchmark's raw material)."""

    requests_served: int = 0
    walkers_served: int = 0
    launches: int = 0  # fused in-memory launches
    oom_launches: int = 0  # partition-scheduler passes
    sharded_launches: int = 0  # device-mesh frontier-exchange drains
    padded_walker_slots: int = 0  # launched slots minus real walkers
    plans_prewarmed: int = 0  # explicit prewarm() selection-plan builds
    #: placements prewarm() has warmed (plan and/or compiled launch trace)
    prewarmed_placements: tuple = ()
    # --- streaming (serve.stream) ---------------------------------------
    stream_requests: int = 0  # admitted through StreamingSamplingService
    stream_launches: int = 0  # cohort launches the scheduling loop issued
    stream_failed_requests: int = 0  # futures completed with an error
    stream_deadline_misses: int = 0  # deadline'd requests delivered late
    stream_quota_rejections: int = 0  # tenant token-bucket AdmissionErrors
    #: per-request queue/launch/total latency (RequestLatency entries, in
    #: delivery order) — the open-loop benchmark's raw material
    stream_latencies: list = dataclasses.field(default_factory=list)


def _slice_result(req: SamplingRequest, walks: np.ndarray) -> RequestResult:
    """Cut one request's rows out of a launch: drop row padding, truncate the
    depth bucket to the request's own walk length, recompute the per-request
    summary the standalone engine would have reported."""
    w = walks[: req.num_walkers, : req.depth + 1]
    lengths = (w >= 0).sum(axis=1).astype(np.int32)
    sampled = int(np.maximum(lengths - 1, 0).sum())
    return RequestResult(req.request_id, w, lengths, sampled)


class SamplingService:
    """Fuses concurrent sampling requests into shared device launches.

    Construct with EITHER an in-memory ``graph`` (requests run through the
    fused ``random_walk_segments`` path) OR host-resident ``partitions`` +
    ``total_vertices`` (requests run through the §V out-of-memory
    frontier-queue drain) OR a ``graph`` plus ``mesh`` and
    ``placement="sharded"`` (the graph is range-sharded over the mesh and
    cohorts run through the owner-routed frontier exchange,
    ``repro.shard`` / DESIGN.md §12).  ``submit()`` admits a request
    (raising :class:`~repro.serve.queue.AdmissionError` over capacity) and
    returns a request id; ``drain()`` serves everything pending and returns
    ``{request_id: RequestResult}``.

    On the in-memory path each request gets its own PRNG key (derived from
    the service key and the request id unless passed explicitly), so a
    request's result does not depend on which other requests happen to
    share its launch.  OOM- and shard-routed cohorts are different by
    construction: both merge all member requests into one flat instance
    axis under a single launch-level key, so results are deterministic for
    a fixed submission set but NOT composition-independent, and per-request
    ``key=`` values are unused there (see DESIGN.md §11/§12).
    """

    def __init__(
        self,
        graph: Optional[CSRGraph] = None,
        *,
        partitions: Optional[List[RangePartition]] = None,
        total_vertices: Optional[int] = None,
        max_degree: Optional[int] = None,
        method: str = "its_brs",
        backend: bk.Backend = "auto",
        config: Optional[ServiceConfig] = None,
        key: Optional[jax.Array] = None,
        oom_memory_capacity: int = 2,
        oom_num_streams: int = 2,
        oom_chunk: int = 1024,
        mesh: Optional[Mesh] = None,
        placement: Optional[str] = None,
        shard_axis: str = "data",
    ):
        if (graph is None) == (partitions is None):
            raise ValueError(
                "pass exactly one of graph= (in-memory / sharded) or "
                "partitions= (out-of-memory)"
            )
        if placement is None:
            placement = "oom" if partitions is not None else (
                "sharded" if mesh is not None else "memory"
            )
        if placement not in ("memory", "oom", "sharded"):
            raise ValueError(f"unknown placement {placement!r}")
        if placement == "sharded" and (graph is None or mesh is None):
            raise ValueError('placement="sharded" needs graph= and mesh=')
        if placement != "sharded" and mesh is not None:
            # a mesh the service would silently never use means the caller
            # configured one execution path and got another
            raise ValueError(
                f'mesh= is only meaningful with placement="sharded", '
                f"got placement={placement!r}"
            )
        if placement == "oom" and partitions is None:
            raise ValueError('placement="oom" needs partitions=')
        if placement == "memory" and graph is None:
            raise ValueError('placement="memory" needs graph=')
        self.placement = placement
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.graph = graph
        self.partitions = partitions
        if graph is not None:
            self.num_vertices = graph.num_vertices
            self.max_degree = int(max_degree or graph.max_degree())
        else:
            if total_vertices is None:
                raise ValueError("partitions= needs total_vertices=")
            self.num_vertices = int(total_vertices)
            if max_degree is None:
                max_degree = max(
                    (int(np.diff(p.indptr).max()) for p in partitions if p.num_vertices),
                    default=1,
                )
            self.max_degree = int(max_degree)
        self.method = method
        self.backend = backend
        self.config = config or ServiceConfig()
        self._queue = RequestQueue(self.config)
        base = key if key is not None else jax.random.PRNGKey(0)
        # disjoint streams: per-request keys fold request ids into _key,
        # OOM partition-scheduler passes fold launch counters into _oom_key
        self._key, self._oom_key = jax.random.split(base)
        self._next_id = 0
        self._oom_launch = 0
        self._oom_kwargs = dict(
            memory_capacity=oom_memory_capacity,
            num_streams=oom_num_streams,
            chunk=oom_chunk,
        )
        self.stats = ServiceStats()

    # -- intake ------------------------------------------------------------

    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(
        self,
        seeds,
        *,
        depth: int,
        spec: SamplingSpec,
        key: Optional[jax.Array] = None,
    ) -> int:
        """Admit one request; returns its id (the ``drain()`` result key).

        ``seeds``: (n,) start vertices in ``[0, num_vertices)``; ``depth``:
        walk length in steps; ``spec``: the request's sampling algorithm;
        ``key``: the request's PRNG stream (in-memory serving only — the
        OOM drain keys per launch, not per request).
        Raises :class:`~repro.serve.queue.AdmissionError` on malformed or
        over-capacity requests — admission happens HERE, not at drain time,
        so callers get back-pressure while they can still shed load.
        """
        req = self._make_request(seeds, depth=depth, spec=spec, key=key)
        self._queue.submit(req)  # may raise — then the id is NOT consumed
        self._next_id += 1
        return req.request_id

    def _make_request(
        self, seeds, *, depth: int, spec: SamplingSpec,
        key: Optional[jax.Array] = None,
    ) -> SamplingRequest:
        """Validate seeds and build the next :class:`SamplingRequest` —
        shared by batch ``submit`` and the streaming front door
        (``serve.stream``), so both allocate ids and per-request keys from
        the same sequence.  Does NOT consume the id: callers bump
        ``_next_id`` only after their own admission checks pass."""
        seeds = np.asarray(seeds)
        if seeds.ndim == 1 and seeds.size and (
            seeds.min() < 0 or seeds.max() >= self.num_vertices
        ):
            raise AdmissionError(
                f"seeds outside [0, num_vertices={self.num_vertices}): "
                f"min={seeds.min()} max={seeds.max()}"
            )
        rid = self._next_id
        return SamplingRequest(
            request_id=rid,
            # always copy: the queue holds the array past this call, and a
            # caller mutating its buffer would bypass the range check above
            seeds=np.array(seeds, dtype=np.int32),
            depth=int(depth),
            spec=spec,
            key=key if key is not None else jax.random.fold_in(self._key, rid),
        )

    def prewarm(
        self,
        spec: SamplingSpec,
        *,
        depth: Optional[int] = None,
        width: Optional[int] = None,
        requests: int = 1,
    ) -> tuple:
        """Warm ``spec``'s serving path NOW, so no live request pays it.

        Two independent layers, covering every placement:

        1. **Selection plan** (memory and sharded placements, flat-bias
           specs): build the adaptive method plan and its alias/rejection
           tables (DESIGN.md §13).  They live in the per-(graph, bias fn)
           cache of ``core.methods`` — the sharded drain reuses the
           full-graph plan, so one build serves both placements.  OOM
           tables are partition-local and built at first residency inside
           the drain; the compile warm below triggers exactly that.
        2. **Launch trace** (all placements): when ``depth`` is given, run
           one throwaway launch at the padded geometry a request of
           ``(width, depth)`` would occupy — ``width`` defaults to the
           smallest walker bucket; ``requests`` sizes the fused request
           axis on the memory placement — through the placement's real
           engine entry point, so the jit trace (and, for OOM, the lazy
           partition tables) exist before traffic arrives.  Without it, a
           first streaming request on the partitioned or sharded paths
           eats a multi-second compile inside its latency budget.

        The warm launch uses a fixed throwaway key and does not advance
        the service's request-id or launch-key sequences, so prewarming
        never changes what any subsequent request samples.  Returns the
        per-cohort method plan (empty when there is nothing to plan).
        """
        program = tp.lower(spec)
        methods: tuple = ()
        if self.placement != "oom" and program.mode == "flat":
            methods, _tables = flat_method_plan(self.graph, program, self.max_degree)
            self.stats.plans_prewarmed += 1
        if depth is not None:
            self._prewarm_launch(spec, depth=depth, width=width, requests=requests)
        if self.placement not in self.stats.prewarmed_placements:
            self.stats.prewarmed_placements += (self.placement,)
        return methods

    def _prewarm_launch(
        self, spec: SamplingSpec, *, depth: int, width: Optional[int],
        requests: int,
    ) -> None:
        """One throwaway launch at the bucketed geometry, placement-routed.

        Seeds are vertex 0 plus ``-1`` padding (an all-padding launch would
        terminate before the OOM/sharded drain bodies ever compile); the
        key is a constant, and no service stats/counters move, so the warm
        launch is invisible to serving semantics and benchmarks alike.
        """
        cfg = self.config
        depth_b = _pow2_bucket(int(depth), cfg.min_depth_bucket)
        width_b = _pow2_bucket(int(width or 1), cfg.min_walker_bucket)
        key = jax.random.PRNGKey(0)
        if self.placement == "memory":
            r_pad = _pow2_bucket(max(int(requests), 1), 1)
            seeds = np.full((r_pad, width_b), -1, np.int32)
            seeds[:, 0] = 0
            keys = jnp.stack([key] * r_pad)
            random_walk_segments(
                self.graph, jnp.asarray(seeds), keys, depth=depth_b,
                spec=spec, max_degree=self.max_degree, method=self.method,
                backend=self.backend,
            ).walks.block_until_ready()
            return
        # OOM / sharded: cohorts pack one flat instance axis (128-multiple,
        # mirroring _pack_flat) with per-instance depth limits
        i_pad = _pow2_bucket(width_b * max(int(requests), 1), 128)
        seeds = np.full((i_pad,), -1, np.int32)
        seeds[0] = 0
        limits = np.zeros((i_pad,), np.int32)
        limits[0] = depth_b
        if self.placement == "oom":
            oom_random_walk(
                self.partitions, self.num_vertices, seeds, key,
                depth=depth_b, spec=spec, max_degree=self.max_degree,
                backend=self.backend, depth_limits=limits, **self._oom_kwargs,
            )
        else:
            jax.block_until_ready(sharded_random_walk(
                self.mesh, self.graph, seeds, key, depth=depth_b, spec=spec,
                max_degree=self.max_degree, axis=self.shard_axis,
                backend=self.backend, depth_limits=limits,
            ).walks)

    # -- serving -----------------------------------------------------------

    def drain(self) -> Dict[int, RequestResult]:
        """Serve every pending request; returns ``{request_id: result}``.

        If a cohort launch fails, its requests and every not-yet-served
        cohort's are re-queued and a :class:`DrainError` carrying the
        already-completed results is raised — no admitted request is ever
        silently dropped.
        """
        out: Dict[int, RequestResult] = {}
        cohorts = self._queue.take_cohorts(bucket_by_shape=self.placement == "memory")
        for i, cohort in enumerate(cohorts):
            try:
                self._run_cohort(cohort, out)
            except Exception as e:
                # _run_sequential may have partially filled `out` for this
                # cohort; don't serve those twice on retry
                for c in cohorts[i:]:
                    for req in c.requests:
                        if req.request_id not in out:
                            self._queue.submit(req)  # fits: was admitted before
                raise DrainError(
                    f"cohort launch failed ({type(e).__name__}: {e}); "
                    f"unserved requests re-queued, {len(out)} completed "
                    f"results on .completed",
                    out,
                ) from e
        return out

    def _run_cohort(self, cohort: Cohort, out: Dict[int, RequestResult]) -> None:
        """Launch one cohort through this service's placement (the single
        dispatch point ``drain()`` and the streaming scheduler share) and
        account it.  On failure, ``out`` holds whatever the launch delivered
        before raising (only the sequential path delivers partially)."""
        if self.placement == "oom":
            self._run_oom(cohort, out)
        elif self.placement == "sharded":
            self._run_sharded(cohort, out)
        elif self.config.fuse:
            self._run_fused(cohort, out)
        else:
            self._run_sequential(cohort, out)
        self.stats.requests_served += len(cohort.requests)
        self.stats.walkers_served += cohort.num_walkers

    def _pack(self, cohort: Cohort) -> tuple:
        """Pad cohort members into the launch geometry: ``(R_pad, W)`` seeds
        (rows beyond ``R`` are all--1 ghosts so the request axis is also
        bucketed) and ``R_pad`` stacked keys."""
        reqs = cohort.requests
        r_pad = _pow2_bucket(len(reqs), 1)
        seeds = np.full((r_pad, cohort.width), -1, np.int32)
        for i, req in enumerate(reqs):
            seeds[i, : req.num_walkers] = req.seeds
        keys = jnp.stack(
            [r.key for r in reqs]
            + [jax.random.PRNGKey(0)] * (r_pad - len(reqs))
        )
        return jnp.asarray(seeds), keys, r_pad

    def _run_fused(self, cohort: Cohort, out: Dict[int, RequestResult]) -> None:
        seeds, keys, r_pad = self._pack(cohort)
        res = random_walk_segments(
            self.graph, seeds, keys, depth=cohort.depth,
            spec=cohort.requests[0].spec, max_degree=self.max_degree,
            method=self.method, backend=self.backend,
        )
        walks = np.asarray(res.walks)
        for i, req in enumerate(cohort.requests):
            out[req.request_id] = _slice_result(req, walks[i])
        self.stats.launches += 1
        self.stats.padded_walker_slots += r_pad * cohort.width - cohort.num_walkers

    def _run_sequential(self, cohort: Cohort, out: Dict[int, RequestResult]) -> None:
        """One launch per request, same padded geometry as the fused path —
        the bit-identical baseline the benchmark compares against."""
        for req in cohort.requests:
            row = np.full((cohort.width,), -1, np.int32)
            row[: req.num_walkers] = req.seeds
            res = random_walk(
                self.graph, jnp.asarray(row), req.key, depth=cohort.depth,
                spec=req.spec, max_degree=self.max_degree,
                method=self.method, backend=self.backend,
            )
            out[req.request_id] = _slice_result(req, np.asarray(res.walks))
            self.stats.launches += 1
            self.stats.padded_walker_slots += cohort.width - req.num_walkers

    def _pack_flat(self, cohort: Cohort) -> tuple:
        """Merge a cohort's requests into one flat instance axis: ``-1``-
        padded seeds and per-instance ``depth_limits`` (power-of-two
        instance count so recurring cohort shapes reuse the drain trace),
        plus ``(request, row offset)`` spans for unpacking and the
        launch-level key (one per partition-scheduling pass — the OOM and
        sharded drains key per launch, not per request)."""
        total = cohort.num_walkers
        i_pad = _pow2_bucket(total, 128)
        seeds = np.full((i_pad,), -1, np.int32)
        limits = np.zeros((i_pad,), np.int32)
        spans = []
        at = 0
        for req in cohort.requests:
            n = req.num_walkers
            seeds[at : at + n] = req.seeds
            limits[at : at + n] = req.depth
            spans.append((req, at))
            at += n
        self._oom_launch += 1
        key = jax.random.fold_in(self._oom_key, self._oom_launch)
        return seeds, limits, spans, key, i_pad - total

    @staticmethod
    def _unpack_flat(spans, walks: np.ndarray, out: Dict[int, RequestResult]) -> None:
        for req, at in spans:
            out[req.request_id] = _slice_result(req, walks[at : at + req.num_walkers])

    def _run_oom(self, cohort: Cohort, out: Dict[int, RequestResult]) -> None:
        """Route one cohort through the §V frontier-queue drain: member
        requests merge into one flat instance axis (per-instance
        ``depth_limits`` let mixed walk lengths share the partition
        schedule)."""
        seeds, limits, spans, key, ghost = self._pack_flat(cohort)
        walks, _stats = oom_random_walk(
            self.partitions, self.num_vertices, seeds, key,
            depth=cohort.depth, spec=cohort.requests[0].spec,
            max_degree=self.max_degree, backend=self.backend,
            depth_limits=limits, **self._oom_kwargs,
        )
        self._unpack_flat(spans, walks, out)
        self.stats.oom_launches += 1
        self.stats.padded_walker_slots += ghost

    def _run_sharded(self, cohort: Cohort, out: Dict[int, RequestResult]) -> None:
        """Route one cohort through the owner-routed mesh drain
        (``repro.shard``, DESIGN.md §12): same flat-instance-axis packing
        and launch-key contract as the OOM path."""
        seeds, limits, spans, key, ghost = self._pack_flat(cohort)
        res = sharded_random_walk(
            self.mesh, self.graph, seeds, key,
            depth=cohort.depth, spec=cohort.requests[0].spec,
            max_degree=self.max_degree, axis=self.shard_axis,
            backend=self.backend, depth_limits=limits,
        )
        self._unpack_flat(spans, np.asarray(res.walks), out)
        self.stats.sharded_launches += 1
        self.stats.padded_walker_slots += ghost
