"""Selection backend dispatcher: reference jnp vs compiled Pallas (DESIGN.md §6).

The engines never call the Pallas kernels directly — every ``select_*`` call
routes through this module, which owns the plumbing the kernels need:

- backend resolution: ``"auto"`` compiles through Mosaic on TPU and falls
  back to the pure-jnp reference path elsewhere (interpret-mode kernels are
  correct everywhere but only *fast* on TPU);
- lane-aligned padding of candidate pools to multiples of 128 (zero-bias pad
  candidates get zero-width CTPS regions, so results are unchanged);
- pre-generated counted-RNG retry budgets (:func:`repro.core.select.retry_randoms`)
  so the kernel's fixed ``ITERS`` unroll consumes bit-for-bit the same
  uniforms as the reference retry loop — ``backend="pallas"`` and
  ``backend="reference"`` agree exactly whenever the budget suffices;
- degree-bucketed walk scheduling (:func:`walk_step_bucketed`): per step,
  walkers are partitioned by degree into small/medium cohorts served by
  :func:`repro.kernels.walk_step.walk_step_pallas` with per-bucket
  ``max_seg`` windows, and a huge-degree cohort served by the chunked
  two-pass scan — the TPU analogue of the paper's workload-aware
  (KnightKing-style) scheduling.
"""
from __future__ import annotations

from typing import Literal, Mapping

import jax
import jax.numpy as jnp

from repro.core import select as sel
from repro.kernels.its_select import its_select_pallas
from repro.kernels.walk_step import pad_csr_for_kernel, walk_step_pallas

Backend = Literal["auto", "reference", "pallas"]

#: candidate pools are padded to multiples of the TPU lane width
LANES = 128

#: default degree-bucket ladder for the walk fast path (DESIGN.md §6):
#: deg ∈ (0, 128] → small cohort, (128, 512] → medium cohort, > 512 → chunked
WALK_BUCKETS = (128, 512)

#: chunk width of the two-pass huge-degree scan
CHUNK = 512


def resolve_backend(backend: str = "auto") -> str:
    """Resolve ``"auto"`` → ``"pallas"`` on TPU, ``"reference"`` elsewhere."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if backend not in ("reference", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (use auto/reference/pallas)")
    return backend


def pad_lanes(biases: jax.Array) -> jax.Array:
    """Pad the candidate (last) dim to a lane multiple with zero bias."""
    p = biases.shape[-1]
    pad = (-p) % LANES
    if pad:
        biases = jnp.pad(biases, [(0, 0)] * (biases.ndim - 1) + [(0, pad)])
    return biases


def _masked(biases: jax.Array, mask: jax.Array | None) -> jax.Array:
    b = jnp.maximum(biases.astype(jnp.float32), 0.0)
    if mask is not None:
        b = jnp.where(mask, b, 0.0)
    return b


def select_without_replacement(
    key: jax.Array,
    biases: jax.Array,
    mask: jax.Array | None,
    k: int,
    *,
    method: sel.SelectMethod = "its_brs",
    backend: Backend = "auto",
    max_iters: int = 32,
    blk_i: int = 8,
) -> sel.SelectResult:
    """Backend-dispatched without-replacement selection.

    ``its_brs`` has a fused Pallas kernel; ``gumbel`` is already TPU-native
    vector code and ``repeated``/``updated`` are diagnostic baselines, so all
    three run the reference implementation on every backend.  With the same
    ``max_iters`` the two backends agree bit-for-bit on indices, validity and
    the iteration/search counters (shared counted-RNG budget).
    """
    be = resolve_backend(backend)
    if be == "reference" or method != "its_brs":
        return sel.select_without_replacement(key, biases, mask, k, method=method, max_iters=max_iters)

    b = _masked(biases, mask)
    batch_shape = b.shape[:-1]
    p = b.shape[-1]
    rands = sel.retry_randoms(key, batch_shape, max_iters, k)
    bf = pad_lanes(b.reshape(-1, p))
    rf = rands.reshape(-1, max_iters, k)
    idx, stats = its_select_pallas(bf, rf, blk_i=blk_i, with_stats=True)
    idx = idx.reshape(batch_shape + (k,))
    stats = stats.reshape(batch_shape + (2,))
    return sel.SelectResult(idx, idx >= 0, stats[..., 0], stats[..., 1])


def select_with_replacement(
    key: jax.Array,
    biases: jax.Array,
    mask: jax.Array | None,
    k: int,
    *,
    backend: Backend = "auto",
    blk_i: int = 8,
) -> jax.Array:
    """Backend-dispatched with-replacement ITS draw (random-walk case).

    Only ``k == 1`` has a kernel route (a single draw cannot self-collide, so
    the without-replacement kernel with a one-round budget computes exactly
    the with-replacement draw); larger ``k`` runs the reference path.
    Degenerate all-zero rows return ``P - 1`` like the reference (callers
    mask dead instances).
    """
    be = resolve_backend(backend)
    if be == "reference" or k != 1:
        return sel.select_with_replacement(key, biases, mask, k)
    b = _masked(biases, mask)
    batch_shape = b.shape[:-1]
    p = b.shape[-1]
    # same bits as the reference's uniform(key, batch + (1,)) draw
    r = jax.random.uniform(key, tuple(batch_shape) + (1, 1), dtype=jnp.float32)
    idx = its_select_pallas(pad_lanes(b.reshape(-1, p)), r.reshape(-1, 1, 1), blk_i=blk_i)
    idx = idx.reshape(batch_shape + (1,))
    return jnp.where(idx >= 0, idx, p - 1)


# ---------------------------------------------------------------------------
# Degree-bucketed walk scheduling (DESIGN.md §6)
# ---------------------------------------------------------------------------


def walk_bucket_plan(max_degree: int, segs: tuple = WALK_BUCKETS) -> tuple[tuple, bool]:
    """Static per-graph schedule: kernel segment sizes + need for chunked tail.

    Returns ``(buckets, use_chunked)``: one :func:`walk_step_pallas` cohort
    per bucket segment, plus the two-pass chunked scan for degrees above the
    last segment.  Buckets the graph cannot populate are dropped at trace
    time.
    """
    buckets = []
    lo = 0
    for s in segs:
        if max_degree > lo:
            buckets.append(s)
        lo = s
    if not buckets:
        buckets = [segs[0]]
    return tuple(buckets), max_degree > segs[-1]


def pad_walk_csr(indices: jax.Array, flat_bias: jax.Array, buckets: tuple) -> dict:
    """Pre-pad flat CSR edge arrays once, shared by every bucket.

    One padding to the largest segment satisfies all smaller ones: the
    padded length is a multiple of every smaller ``seg`` (segments are
    powers-of-two multiples of 128) and the single spare ``buckets[-1]``
    block covers each cohort's ``blk+1`` window, so no per-bucket copies
    of the (E,) arrays are materialized.
    """
    big = max(buckets)
    padded = pad_csr_for_kernel(indices, flat_bias, big)
    assert all(big % seg == 0 for seg in buckets), buckets
    return {seg: padded for seg in buckets}


def walk_step_bucketed(
    key: jax.Array,
    indptr: jax.Array,
    indices: jax.Array,
    flat_bias: jax.Array,
    padded: Mapping[int, tuple],
    cur: jax.Array,
    *,
    buckets: tuple,
    use_chunked: bool,
    interpret: bool | None = None,
) -> jax.Array:
    """One bias-weighted transition for all walkers, scheduled by degree.

    ``flat_bias`` is the (E,) per-edge bias aligned with CSR order
    (``SamplingSpec.flat_edge_bias``); ``padded`` maps each bucket segment to
    its :func:`pad_csr_for_kernel` output.  Walkers outside a cohort run with
    ``deg = 0`` (a dead-end no-op) and take their result from their own
    cohort.  Returns next vertices (W,) int32; -1 for finished walkers and
    dead ends.
    """
    safe = jnp.maximum(cur, 0)
    starts = indptr[safe]
    deg = jnp.where(cur >= 0, indptr[safe + 1] - starts, 0)
    r = jax.random.uniform(jax.random.fold_in(key, 0), cur.shape, dtype=jnp.float32)

    nxt = jnp.full_like(cur, -1)
    lo = 0
    for seg in buckets:
        inds_p, bias_p = padded[seg]
        inb = (deg > lo) & (deg <= seg)
        cand = walk_step_pallas(
            jnp.where(inb, starts, 0),
            jnp.where(inb, deg, 0),
            inds_p,
            bias_p,
            r,
            max_seg=seg,
            interpret=interpret,
        )
        nxt = jnp.where(inb, cand, nxt)
        lo = seg

    if use_chunked:
        huge = deg > buckets[-1]
        safe_cur = jnp.where(huge, safe, 0)
        off = sel.walk_transition_chunked(
            jax.random.fold_in(key, 1), indptr, flat_bias, safe_cur, chunk=CHUNK
        )
        eidx = jnp.clip(indptr[safe_cur] + jnp.maximum(off, 0), 0, indices.shape[0] - 1)
        cand = jnp.where(off >= 0, indices[eidx], -1)
        nxt = jnp.where(huge, cand, nxt)
    return nxt
