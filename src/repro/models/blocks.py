"""Composable decoder blocks: one function family per layer kind.

Kinds: "global" | "local" (attention), "rglru" (Griffin), "mlstm" | "slstm"
(xLSTM).  Heterogeneous stacks (gemma3 5:1 local:global, recurrentgemma
2:1 rglru:attn, xLSTM 7:1) scan over *pattern superblocks* — see model.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import ParamDef, rms_norm


ATTN_KINDS = ("global", "local", "global_dense")


def block_defs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    defs: dict = {"norm1": ParamDef((d,), (None,), init="zeros")}
    if kind in ATTN_KINDS:
        defs["attn"] = attn.attn_defs(cfg)
        if cfg.num_experts and kind != "global_dense":
            defs["norm2"] = ParamDef((d,), (None,), init="zeros")
            defs["moe"] = moe_mod.moe_defs(cfg)
            if cfg.moe_dense_ff:
                defs["dense_ffn"] = ffn_mod.ffn_defs(cfg, cfg.moe_dense_ff)
        elif cfg.d_ff:
            defs["norm2"] = ParamDef((d,), (None,), init="zeros")
            defs["ffn"] = ffn_mod.ffn_defs(cfg)
    elif kind == "rglru":
        defs["rnn"] = rec.rglru_defs(cfg)
        defs["norm2"] = ParamDef((d,), (None,), init="zeros")
        defs["ffn"] = ffn_mod.ffn_defs(cfg)
    elif kind == "mlstm":
        defs["cell"] = rec.mlstm_defs(cfg)
    elif kind == "slstm":
        defs["cell"] = rec.slstm_defs(cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return defs


def block_train(
    params: dict, cfg: ModelConfig, kind: str, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.window_size if kind == "local" else 0
        x = x + attn.attention_train(params["attn"], cfg, h, positions, window=window)
        if cfg.num_experts and kind != "global_dense":
            h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
            y, aux = moe_mod.moe_apply(params["moe"], cfg, h2)
            if cfg.moe_dense_ff:
                y = y + ffn_mod.ffn_apply(params["dense_ffn"], cfg, h2)
            x = x + y
        elif cfg.d_ff:
            h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
            x = x + ffn_mod.ffn_apply(params["ffn"], cfg, h2)
    elif kind == "rglru":
        x = x + rec.rglru_train(params["rnn"], cfg, h)
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + ffn_mod.ffn_apply(params["ffn"], cfg, h2)
    elif kind == "mlstm":
        x = x + rec.mlstm_train(params["cell"], cfg, h)
    elif kind == "slstm":
        x = x + rec.slstm_train(params["cell"], cfg, h)
    return x, aux


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype) -> dict:
    if kind in ATTN_KINDS:
        s = min(cfg.window_size, max_len) if kind == "local" and cfg.window_size else max_len
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((batch, s, kvh, hd), dtype),
            "v": jnp.zeros((batch, s, kvh, hd), dtype),
        }
    if kind == "rglru":
        return rec.rglru_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return rec.mlstm_init_state(cfg, batch, dtype)
    if kind == "slstm":
        return rec.slstm_init_state(cfg, batch, dtype)
    raise ValueError(kind)


def block_decode(
    params: dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    cache: dict,
    cache_index: jax.Array,
) -> Tuple[jax.Array, dict]:
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.window_size if kind == "local" else 0
        y, ck, cv = attn.attention_decode(
            params["attn"], cfg, h, cache["k"], cache["v"], cache_index, window=window
        )
        x = x + y
        cache = {"k": ck, "v": cv}
        if cfg.num_experts and kind != "global_dense":
            h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
            y, _ = moe_mod.moe_apply(params["moe"], cfg, h2)
            if cfg.moe_dense_ff:
                y = y + ffn_mod.ffn_apply(params["dense_ffn"], cfg, h2)
            x = x + y
        elif cfg.d_ff:
            h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
            x = x + ffn_mod.ffn_apply(params["ffn"], cfg, h2)
    elif kind == "rglru":
        y, cache = rec.rglru_decode(params["rnn"], cfg, h, cache)
        x = x + y
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + ffn_mod.ffn_apply(params["ffn"], cfg, h2)
    elif kind == "mlstm":
        y, cache = rec.mlstm_decode(params["cell"], cfg, h, cache)
        x = x + y
    elif kind == "slstm":
        y, cache = rec.slstm_decode(params["cell"], cfg, h, cache)
        x = x + y
    return x, cache
