"""Sampling engines: walks, traversal sampling, algorithm zoo semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core.engine import random_walk, traversal_sample
from repro.graph import powerlaw_graph, erdos_renyi_graph


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(256, seed=1, weighted=True)


def edges_set(g):
    ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
    return {(a, b) for a in range(len(ip) - 1) for b in ind[ip[a] : ip[a + 1]]}


@pytest.fixture(scope="module")
def graph_edges(graph):
    return edges_set(graph)


KEY = jax.random.PRNGKey(0)


class TestRandomWalk:
    @pytest.mark.parametrize("name", ["deepwalk", "biased_rw", "weighted_rw", "node2vec"])
    def test_walk_edges_exist(self, graph, graph_edges, name):
        spec = alg.ALGORITHMS[name]()
        seeds = jax.random.randint(KEY, (48,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=12, spec=spec, max_degree=graph.max_degree())
        walks = np.asarray(res.walks)
        assert walks.shape == (48, 13)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert (a, b) in graph_edges

    def test_mhrw_stays_or_moves(self, graph, graph_edges):
        spec = alg.metropolis_hastings_walk()
        seeds = jax.random.randint(KEY, (48,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=12, spec=spec, max_degree=graph.max_degree())
        for row in np.asarray(res.walks):
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert a == b or (a, b) in graph_edges

    def test_restart_returns_home(self, graph):
        spec = alg.random_walk_with_restart(1.0, home=7)
        seeds = jnp.full((8,), 3, jnp.int32)
        res = random_walk(graph, seeds, KEY, depth=5, spec=spec, max_degree=graph.max_degree())
        walks = np.asarray(res.walks)
        alive = walks[:, 1:][walks[:, 1:] >= 0]
        assert (alive == 7).all()

    def test_jump_changes_distribution(self, graph):
        spec = alg.random_walk_with_jump(1.0, graph.num_vertices)
        seeds = jnp.zeros((64,), jnp.int32)
        res = random_walk(graph, seeds, KEY, depth=10, spec=spec, max_degree=graph.max_degree())
        # jumps can land anywhere, including non-neighbors
        walks = np.asarray(res.walks)
        assert len(np.unique(walks[:, 1])) > 10

    def test_biased_walk_prefers_high_degree(self, graph):
        deg = np.asarray(graph.indptr[1:] - graph.indptr[:-1])
        seeds = jax.random.randint(KEY, (512,), 0, graph.num_vertices)
        unb = random_walk(graph, seeds, KEY, depth=20, spec=alg.deepwalk(), max_degree=graph.max_degree())
        bia = random_walk(graph, seeds, KEY, depth=20, spec=alg.biased_random_walk(), max_degree=graph.max_degree())
        mean_deg = lambda w: deg[np.asarray(w.walks)[:, 1:].clip(0)].mean()
        assert mean_deg(bia) > mean_deg(unb)

    def test_deepwalk_stationary_distribution(self, graph):
        """Simple RW on undirected graph: stationary dist ∝ degree."""
        seeds = jax.random.randint(KEY, (2048,), 0, graph.num_vertices)
        res = random_walk(graph, seeds, KEY, depth=50, spec=alg.deepwalk(), max_degree=graph.max_degree())
        last = np.asarray(res.walks)[:, -1]
        last = last[last >= 0]
        deg = np.asarray(graph.indptr[1:] - graph.indptr[:-1]).astype(float)
        visit = np.bincount(last, minlength=graph.num_vertices).astype(float)
        # correlation between visit frequency and degree should be strong
        corr = np.corrcoef(visit, deg)[0, 1]
        assert corr > 0.7, corr


class TestTraversalSampling:
    @pytest.mark.parametrize("name", ["neighbor_biased", "neighbor_unbiased", "forest_fire", "layer", "snowball"])
    def test_sampled_edges_exist(self, graph, graph_edges, name):
        spec = alg.ALGORITHMS[name]()
        pools = jax.random.randint(KEY, (16, 1), 0, graph.num_vertices)
        res = traversal_sample(graph, pools, KEY, depth=2, spec=spec,
                               max_degree=graph.max_degree(), pool_capacity=128,
                               max_vertices=graph.num_vertices)
        src, dst = np.asarray(res.edges_src), np.asarray(res.edges_dst)
        n_checked = 0
        for s_row, d_row in zip(src, dst):
            for s, d in zip(s_row, d_row):
                if s >= 0 and d >= 0:
                    assert (s, d) in graph_edges
                    n_checked += 1
        assert n_checked > 0

    def test_without_replacement_within_run(self, graph):
        """Traversal sampling never samples the same vertex twice."""
        spec = alg.unbiased_neighbor_sampling(neighbor_size=2, frontier_size=4)
        pools = jax.random.randint(KEY, (32, 1), 0, graph.num_vertices)
        res = traversal_sample(graph, pools, KEY, depth=3, spec=spec,
                               max_degree=graph.max_degree(), pool_capacity=128,
                               max_vertices=graph.num_vertices)
        dst = np.asarray(res.edges_dst)
        for i, row in enumerate(dst):
            sampled = row[row >= 0]
            assert len(set(sampled.tolist())) == len(sampled), f"instance {i} resampled a vertex"

    def test_neighbor_size_cap(self, graph):
        spec = alg.biased_neighbor_sampling(neighbor_size=2, frontier_size=4)
        pools = jax.random.randint(KEY, (16, 1), 0, graph.num_vertices)
        res = traversal_sample(graph, pools, KEY, depth=1, spec=spec,
                               max_degree=graph.max_degree(), pool_capacity=64,
                               max_vertices=graph.num_vertices)
        assert int(res.num_edges.max()) <= 4 * 2

    def test_mdrw_pool_invariant(self, graph):
        """MDRW: pool size stays <= initial (replace semantics, paper Fig 4)."""
        spec = alg.multi_dimensional_random_walk()
        pools = jax.random.randint(KEY, (16, 3), 0, graph.num_vertices)
        res = traversal_sample(graph, pools, KEY, depth=6, spec=spec,
                               max_degree=graph.max_degree(), pool_capacity=8)
        sizes = np.asarray((res.frontier_pool >= 0).sum(-1))
        assert (sizes <= 3).all()

    def test_forest_fire_variable_count(self, graph):
        spec = alg.forest_fire_sampling(p_f=0.5, max_burn=6)
        pools = jax.random.randint(KEY, (64, 1), 0, graph.num_vertices)
        res = traversal_sample(graph, pools, KEY, depth=1, spec=spec,
                               max_degree=graph.max_degree(), pool_capacity=64,
                               max_vertices=graph.num_vertices)
        counts = np.asarray(res.num_edges)
        assert len(np.unique(counts)) > 1  # geometric burn: variable sizes


class TestMultiDevice:
    def test_instance_parallel_single_device(self, graph):
        from repro.core.distributed import instance_parallel_walk
        mesh = jax.make_mesh((1,), ("data",))
        seeds = jax.random.randint(KEY, (32,), 0, graph.num_vertices)
        res = instance_parallel_walk(mesh, graph, seeds, KEY, depth=8,
                                     spec=alg.deepwalk(), max_degree=graph.max_degree())
        assert res.walks.shape == (32, 9)
        assert int(res.sampled_edges) > 0

    def test_graph_sharded_single_device(self, graph, graph_edges):
        from repro.core.distributed import graph_sharded_walk
        mesh = jax.make_mesh((1,), ("data",))
        seeds = jax.random.randint(KEY, (16,), 0, graph.num_vertices)
        walks = graph_sharded_walk(mesh, graph, seeds, KEY, depth=6,
                                   spec=alg.deepwalk(), max_degree=graph.max_degree())
        walks = np.asarray(walks)
        for row in walks:
            for a, b in zip(row[:-1], row[1:]):
                if a < 0 or b < 0:
                    break
                assert (a, b) in graph_edges

    def test_graph_sharded_runs_lowered_epilogue(self, graph):
        """The sharded engine applies transition-program epilogues too:
        restart-to-seed (which has no legacy update hook) must work."""
        from repro.core.distributed import graph_sharded_walk
        mesh = jax.make_mesh((1,), ("data",))
        seeds = jax.random.randint(KEY, (8,), 0, graph.num_vertices)
        walks = np.asarray(graph_sharded_walk(
            mesh, graph, seeds, KEY, depth=4,
            spec=alg.random_walk_with_restart(1.0), max_degree=graph.max_degree()))
        for row in walks:
            alive = row[1:][row[1:] >= 0]
            assert (alive == row[0]).all()
