"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step + one decode step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn
from repro.train.optimizer import OptConfig, opt_init, opt_update

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    b, s = 2, 32
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    fe = (jax.random.normal(KEY, (b, cfg.frontend_tokens, cfg.d_model))
          if cfg.frontend != "none" else None)
    logits, aux = forward(params, cfg, toks, fe)
    total = s + (cfg.frontend_tokens if cfg.frontend != "none" else 0)
    assert logits.shape == (b, total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    ocfg = OptConfig(kind="adamw", lr=1e-3, warmup_steps=1)
    opt_state = opt_init(ocfg, params)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    fe = (jax.random.normal(KEY, (2, cfg.frontend_tokens, cfg.d_model))
          if cfg.frontend != "none" else None)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, toks, toks, fe)
    )(params)
    assert np.isfinite(float(loss))
    new_params, _, gnorm = opt_update(ocfg, grads, opt_state, params, jnp.zeros((), jnp.int32))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    cache = init_cache(cfg, 2, 64)
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    lg1, cache = decode_step(params, cfg, toks, cache)
    lg2, cache = decode_step(params, cfg, toks, cache)
    assert lg2.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2)).all()
    assert int(cache["index"]) == 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_sane(arch):
    """The exact assigned configs: structural invariants only (no alloc)."""
    cfg = get_config(arch)
    assert cfg.num_heads % cfg.num_kv_heads == 0
    assert len(cfg.layer_kinds()) == cfg.num_layers
    assert cfg.n_rep * len(cfg.pattern) + cfg.n_tail == cfg.num_layers
    n = cfg.param_count()
    assert n > 1e8, f"{arch}: implausibly small param count {n}"
    if cfg.num_experts:
        assert cfg.active_param_count() < n


def test_assigned_param_counts():
    """Named sizes land near the assignment (approximate formulas)."""
    expect = {
        "xlstm_350m": (0.2e9, 0.5e9),
        "gemma3_1b": (0.8e9, 1.3e9),
        "internlm2_1_8b": (1.5e9, 2.2e9),
        "gemma_7b": (7.5e9, 9.5e9),
        "starcoder2_3b": (2.6e9, 3.5e9),
        "recurrentgemma_9b": (8e9, 11e9),
        "arctic_480b": (430e9, 520e9),
        "llama4_maverick_400b_a17b": (360e9, 440e9),
        "musicgen_medium": (1.0e9, 1.8e9),
        "internvl2_26b": (17e9, 27e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
