"""Property-based cross-engine parity harness (DESIGN.md §12/§14).

The repo's central correctness claim is that every engine realizes the SAME
walk distribution — and for the deterministic pairs, the same *bits*:

- in-memory ``reference`` ↔ ``pallas`` backends: bit-identical.
- in-memory ↔ mesh-sharded ``sharded_random_walk``: bit-identical for every
  non-opaque program (owner routing + hub replication + counted RNG).
- in-memory ↔ batched ``SamplingService``: bit-identical at the service's
  padded launch geometry (per-request keys).
- in-memory ↔ streaming ``StreamingSamplingService``: bit-identical at the
  same padded geometry, for EVERY arrival pattern — submission order,
  inter-arrival gaps, deadlines, and priorities change only launch timing,
  never any request's walks.
- OOM drain: NOT bit-parity with in-memory (per-launch RNG keying and §V
  phantom-degree semantics are documented divergences) — its contracts are
  determinism across scheduling configurations, backend bit-parity, and
  walks-only-along-edges.

Every contract runs twice here: once over the always-on ``SEED_CORPUS`` +
``REGRESSION_CASES`` (plain parametrize — no hypothesis needed), and once
as a hypothesis property over random (graph × spec × method × geometry)
draws (``tests/strategies.py``), bounded by ``PARITY_EXAMPLES`` (default
15) so CI stays fast while local runs can crank it up.  Failures found by
the property pass get pinned into ``strategies.REGRESSION_CASES``.

Multi-device sharded parity (8 host devices, both backends) lives in
``tests/test_shard.py`` — this module runs in-process on a 1-device mesh,
which still exercises the full drain (queues, sub-rounds, deferral, hub
layout plumbing) minus the collective.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import random_walk
from repro.core.oom import oom_random_walk
from repro.core.transition import IdentityEpilogue, lower
from repro.graph.partition import partition_by_vertex_range
from repro.serve import (
    Priority,
    SamplingService,
    StreamConfig,
    StreamingSamplingService,
)
from repro.serve.queue import _pow2_bucket
from repro.shard.walk import sharded_random_walk

from strategies import (
    HAS_HYPOTHESIS,
    REGRESSION_CASES,
    SEED_CORPUS,
    STREAM_CORPUS,
    ParityCase,
    case_args,
    stream_requests,
)

PARITY_EXAMPLES = int(os.environ.get("PARITY_EXAMPLES", "15"))
ALL_CASES = SEED_CORPUS + REGRESSION_CASES
_IDS = [c.label for c in ALL_CASES]


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# The parity contracts, as plain functions both passes share
# ---------------------------------------------------------------------------


def check_backend_parity(case: ParityCase):
    g, seeds, spec, md = case_args(case)
    key = jax.random.PRNGKey(case.key_seed)
    ref = random_walk(g, seeds, key, depth=case.depth, spec=spec,
                      max_degree=md, backend="reference")
    pal = random_walk(g, seeds, key, depth=case.depth, spec=spec,
                      max_degree=md, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref.walks), np.asarray(pal.walks))
    np.testing.assert_array_equal(np.asarray(ref.lengths), np.asarray(pal.lengths))


def check_sharded_parity(case: ParityCase, mesh, backend="reference", **kw):
    g, seeds, spec, md = case_args(case)
    key = jax.random.PRNGKey(case.key_seed)
    solo = random_walk(g, seeds, key, depth=case.depth, spec=spec,
                       max_degree=md, backend=backend)
    sh = sharded_random_walk(mesh, g, seeds, key, depth=case.depth, spec=spec,
                             max_degree=md, backend=backend, **kw)
    np.testing.assert_array_equal(np.asarray(solo.walks), np.asarray(sh.walks))
    assert sh.stats is not None and sh.stats["num_devices"] == 1


def check_service_parity(case: ParityCase):
    g, seeds, spec, md = case_args(case)
    key = jax.random.PRNGKey(case.key_seed)
    svc = SamplingService(g, backend="reference", key=jax.random.PRNGKey(99))
    rid = svc.submit(seeds, depth=case.depth, spec=spec, key=key)
    res = svc.drain()[rid]
    # the service launches at pow2-bucketed geometry with its own row
    # padding; reproduce that launch through the plain engine
    width = _pow2_bucket(len(seeds), svc.config.min_walker_bucket)
    depth_b = _pow2_bucket(case.depth, svc.config.min_depth_bucket)
    row = np.full((width,), -1, np.int32)
    row[: len(seeds)] = seeds
    solo = random_walk(g, jnp.asarray(row), key, depth=depth_b, spec=spec,
                       max_degree=md, backend="reference")
    expect = np.asarray(solo.walks)[: len(seeds), : case.depth + 1]
    np.testing.assert_array_equal(res.walks, expect)


def check_streaming_parity(case: ParityCase, arrival_seed: int,
                           backend: str = "reference"):
    """Streamed requests are bit-identical to standalone padded engine calls
    under an arbitrary arrival pattern.

    The case's seed set is cut into several requests with mixed depths, then
    submitted in a randomized order with randomized inter-arrival gaps,
    deadlines, and priorities (all derived from ``arrival_seed``), against a
    deterministic fake clock polled between submissions — so cohorts really
    do form and launch differently per pattern.  Every request must still
    equal its own standalone ``random_walk`` at the padded geometry: the
    scheduler controls timing, never bits.
    """
    g, spec, md, requests, order, rng = stream_requests(case, arrival_seed)
    base = jax.random.PRNGKey(case.key_seed)
    t = [0.0]
    svc = SamplingService(g, backend=backend, key=jax.random.PRNGKey(99))
    stream = StreamingSamplingService(
        svc, StreamConfig(max_batch_window_ms=10.0, launch_cost_prior_ms=2.0),
        clock=lambda: t[0], start=False,
    )
    futs = {}
    tiers = list(Priority)
    for j in order:
        cut, depth = requests[j]
        deadline = None if rng.random() < 0.5 else float(rng.uniform(1.0, 50.0))
        futs[j] = stream.submit(
            cut, depth=depth, spec=spec,
            key=jax.random.fold_in(base, j),
            deadline_ms=deadline,
            priority=tiers[int(rng.integers(len(tiers)))],
        )
        t[0] += float(rng.uniform(0.0, 0.006))
        stream.poll()  # launches interleave with arrivals per the policy
    t[0] += 1.0
    stream.poll()
    assert stream.pending == 0
    cfg = svc.config
    for j, (cut, depth) in enumerate(requests):
        width = _pow2_bucket(len(cut), cfg.min_walker_bucket)
        depth_b = _pow2_bucket(depth, cfg.min_depth_bucket)
        row = np.full((width,), -1, np.int32)
        row[: len(cut)] = cut
        solo = random_walk(
            g, jnp.asarray(row), jax.random.fold_in(base, j), depth=depth_b,
            spec=spec, max_degree=md, backend=backend,
        )
        expect = np.asarray(solo.walks)[: len(cut), : depth + 1]
        np.testing.assert_array_equal(
            futs[j].result(timeout=0).walks, expect,
            err_msg=f"request {j} (arrival_seed={arrival_seed})",
        )


def check_oom_properties(case: ParityCase, num_partitions=4):
    """The OOM drain's documented contracts (tests/test_oom.py, DESIGN.md §8).

    OOM is deliberately NOT bit-parity with the in-memory engine (per-launch
    RNG keying, §V phantom-degree semantics), and its scheduling knobs
    recompose launches — so across scheduling configs only the WALK SET
    contract holds (same seeds, full coverage, edges only), while rerun- and
    backend-determinism are exact.
    """
    g, seeds, spec, md = case_args(case)
    key = jax.random.PRNGKey(case.key_seed)
    parts = partition_by_vertex_range(g, num_partitions)
    runs = {}
    for tag, kw in {
        "base": dict(batched=True, workload_aware=True),
        "unbatched": dict(batched=False, workload_aware=True),
        "fifo": dict(batched=True, workload_aware=False),
    }.items():
        walks, _ = oom_random_walk(
            parts, g.num_vertices, seeds, key, depth=case.depth, spec=spec,
            max_degree=md, backend="reference", **kw,
        )
        runs[tag] = np.asarray(walks)
    # exact determinism: the SAME config rerun must not change a single bit
    again, _ = oom_random_walk(
        parts, g.num_vertices, seeds, key, depth=case.depth, spec=spec,
        max_degree=md, backend="reference", batched=True, workload_aware=True,
    )
    np.testing.assert_array_equal(runs["base"], np.asarray(again))
    # exact backend parity inside the OOM drain
    pal, _ = oom_random_walk(
        parts, g.num_vertices, seeds, key, depth=case.depth, spec=spec,
        max_degree=md, backend="pallas",
    )
    np.testing.assert_array_equal(runs["base"], np.asarray(pal))
    # scheduling invariance of the walk SET: same seeds column, full depth
    # coverage, and every emitted transition is legal
    for tag, w in runs.items():
        np.testing.assert_array_equal(w[:, 0], seeds, err_msg=tag)
        assert w.shape == (len(seeds), case.depth + 1), tag
        if isinstance(lower(spec).epilogue, IdentityEpilogue):
            assert_walks_follow_edges(g, w)


def assert_walks_follow_edges(graph, walks: np.ndarray):
    """Every consecutive (a, b >= 0) pair must be an edge of ``graph``.

    Only meaningful for identity-epilogue programs — teleport jumps and MH
    stays are legitimate non-edge transitions.
    """
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    for row in walks:
        for a, b in zip(row[:-1], row[1:]):
            if a < 0 or b < 0:
                continue
            assert b in indices[indptr[a] : indptr[a + 1]], (a, b)


# ---------------------------------------------------------------------------
# Pass 1: the always-on corpus (runs with or without hypothesis)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", ALL_CASES, ids=_IDS)
def test_corpus_backend_parity(case):
    check_backend_parity(case)


@pytest.mark.parametrize("case", ALL_CASES, ids=_IDS)
def test_corpus_sharded_parity(case, mesh1):
    check_sharded_parity(case, mesh1)


@pytest.mark.parametrize(
    "case",
    [c for c in ALL_CASES if c.spec in ("node2vec", "mh", "degu_window")],
    ids=lambda c: c.label,
)
def test_corpus_sharded_parity_pallas(case, mesh1):
    # the programs this PR moved off the fallback, through the pallas drain
    check_sharded_parity(case, mesh1, backend="pallas")


@pytest.mark.parametrize("sub_rounds", [2, 3])
def test_corpus_sharded_parity_sub_rounds(sub_rounds, mesh1):
    # round structure must not leak into the bits: extra local sub-rounds
    # between collectives (the real-mesh latency knob, default 1) replay
    # the identical counted streams
    for case in (SEED_CORPUS[4], SEED_CORPUS[9]):  # node2vec + star MH
        check_sharded_parity(case, mesh1, sub_rounds=sub_rounds)


@pytest.mark.parametrize("case", SEED_CORPUS[:6], ids=[c.label for c in SEED_CORPUS[:6]])
def test_corpus_service_parity(case):
    check_service_parity(case)


@pytest.mark.parametrize(
    "sc", STREAM_CORPUS, ids=[sc.label for sc in STREAM_CORPUS]
)
def test_corpus_streaming_parity(sc):
    check_streaming_parity(sc.case, sc.arrival_seed)


@pytest.mark.parametrize(
    "sc", STREAM_CORPUS[:2], ids=[sc.label for sc in STREAM_CORPUS[:2]]
)
def test_corpus_streaming_parity_pallas(sc):
    check_streaming_parity(sc.case, sc.arrival_seed, backend="pallas")


@pytest.mark.parametrize(
    "case",
    [c for c in SEED_CORPUS if c.spec in ("deepwalk", "node2vec", "mh")][:4],
    ids=lambda c: c.label,
)
def test_corpus_oom_properties(case):
    check_oom_properties(case)


# ---------------------------------------------------------------------------
# Pass 2: hypothesis properties over random cases
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    from strategies import walk_cases

    _SETTINGS = dict(
        max_examples=PARITY_EXAMPLES,
        deadline=None,
        derandomize=True,  # CI stability; failures become REGRESSION_CASES
        suppress_health_check=[HealthCheck.too_slow],
    )

    @settings(**_SETTINGS)
    @given(case=walk_cases())
    def test_prop_backend_parity(case):
        check_backend_parity(case)

    @settings(**_SETTINGS)
    @given(case=walk_cases())
    def test_prop_sharded_parity(case):
        check_sharded_parity(case, jax.make_mesh((1,), ("data",)))

    @settings(**_SETTINGS)
    @given(case=walk_cases())
    def test_prop_service_parity(case):
        check_service_parity(case)

    @settings(max_examples=max(PARITY_EXAMPLES // 2, 5), deadline=None,
              derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(case=walk_cases(), arrival_seed=st.integers(0, 7))
    def test_prop_streaming_parity(case, arrival_seed):
        check_streaming_parity(case, arrival_seed)

    @settings(max_examples=max(PARITY_EXAMPLES // 3, 3), deadline=None,
              derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(case=walk_cases())
    def test_prop_oom_properties(case):
        check_oom_properties(case)

else:  # keep the skip visible in reports instead of silently absent

    def test_prop_backend_parity():
        pytest.skip("hypothesis not installed — property pass ran corpus-only")
