"""Loop-aware HLO cost analysis: validated against XLA's own numbers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze, xla_cost_analysis


def test_loop_free_matches_xla_exactly():
    def f(x, w):
        return jnp.dot(x, w)

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 128), jnp.float32),
    ).compile()
    ca = xla_cost_analysis(co)
    mine = analyze(co.as_text())
    assert mine.flops == ca["flops"]
    assert abs(mine.bytes_accessed - ca["bytes accessed"]) / ca["bytes accessed"] < 0.02


def test_scan_multiplies_trip_count():
    def g(x, w):
        def body(c, _):
            return jnp.tanh(jnp.dot(c, w)), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    co = jax.jit(g).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
    ).compile()
    mine = analyze(co.as_text())
    assert mine.flops == 2 * 256 * 512 * 512 * 10
    assert mine.while_count >= 1


def test_nested_scans_compose():
    def h(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.dot(c2, w), None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    co = jax.jit(h).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
    ).compile()
    assert analyze(co.as_text()).flops == 2 * 64 * 128 * 128 * 12


_SYNTH_HLO = """\
HloModule synth

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,128]{1,0} all-reduce(%x), to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,128]) -> f32[8,128] {
  %x = f32[8,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,128]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[8,128]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collectives_counted_with_loop_multiplier():
    """Synthetic while(7) with one all-reduce per iteration."""
    mine = analyze(_SYNTH_HLO)
    assert mine.collectives["all-reduce"]["count"] == 7
    assert mine.collectives["all-reduce"]["bytes"] == 7 * 8 * 128 * 4
    assert mine.wire_bytes == 2 * 7 * 8 * 128 * 4  # ring factor 2


_STACK_HLO = """\
HloModule stack

%body (p: (s32[], f32[4,32], f32[16,4,32])) -> (s32[], f32[4,32], f32[16,4,32]) {
  %p = (s32[], f32[4,32]{1,0}, f32[16,4,32]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = f32[4,32]{1,0} get-tuple-element(%p), index=1
  %stk = f32[16,4,32]{2,1,0} get-tuple-element(%p), index=2
  %g = f32[4,32]{1,0} gather(%stk, %i), offset_dims={0,1}, collapsed_slice_dims={}, start_index_map={0}, index_vector_dim=0, slice_sizes={1,4,32}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,32]{1,0}, f32[16,4,32]{2,1,0}) tuple(%ni, %g, %stk)
}

%cond (p: (s32[], f32[4,32], f32[16,4,32])) -> pred[] {
  %p = (s32[], f32[4,32]{1,0}, f32[16,4,32]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(16)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,32], s: f32[16,4,32]) -> f32[4,32] {
  %x = f32[4,32]{1,0} parameter(0)
  %s = f32[16,4,32]{2,1,0} parameter(1)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,32]{1,0}, f32[16,4,32]{2,1,0}) tuple(%z, %x, %s)
  %w = (s32[], f32[4,32]{1,0}, f32[16,4,32]{2,1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,32]{1,0} get-tuple-element(%w), index=1
}
"""


def test_scan_residual_stack_bytes_discounted():
    """A (16, 4, 32) stack gathered inside a 16-trip loop must be charged
    its full bytes ONCE per sweep, not 16×."""
    mine = analyze(_STACK_HLO)
    stack_bytes = 16 * 4 * 32 * 4
    slice_bytes = 4 * 32 * 4
    # per iteration: read stack/16 + write slice -> per sweep: stack + 16*slice
    expected = stack_bytes + 16 * slice_bytes
    assert abs(mine.bytes_accessed - expected) <= slice_bytes, (
        mine.bytes_accessed, expected)
