"""Microbenchmark: reference vs Pallas ITS selection → BENCH_select.json.

Times the backend dispatcher's two routes on identical inputs (same counted
RNG budget, so both compute the same selections) across several
(instances, pool, k) shapes, and records wall times so the perf trajectory
is measurable PR-over-PR.  On CPU the Pallas route runs in interpret mode —
expect it to LOSE there; the number that matters is the ratio on TPU, where
the kernel fuses CTPS build + search + BRS retry in VMEM.

Usage:  PYTHONPATH=src python benchmarks/bench_select.py [--iters 8]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.common import timeit  # noqa: E402

from repro.core import backend as bk  # noqa: E402

# (instances, pool size, draws) — frontier-select-like, neighbor-select-like,
# and a wide-pool layer-sampling shape; pools deliberately not lane-aligned
# so the dispatcher's padding plumbing is on the timed path.
SHAPES = [
    (128, 256, 4),
    (256, 100, 2),
    (64, 1000, 8),
]

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_select.json"


def bench_shape(i_dim, p, k, max_iters):
    key = jax.random.PRNGKey(i_dim * p + k)
    b = jax.random.uniform(key, (i_dim, p))

    def run(backend):
        @jax.jit
        def fn(key, b):
            return bk.select_without_replacement(
                key, b, None, k, method="its_brs", backend=backend, max_iters=max_iters
            ).indices
        return timeit(fn, key, b, warmup=1, iters=3)

    t_ref = run("reference")
    t_pal = run("pallas")
    return {
        "instances": i_dim,
        "pool": p,
        "k": k,
        "max_iters": max_iters,
        "reference_s": t_ref,
        "pallas_s": t_pal,
        "speedup": t_ref / t_pal if t_pal > 0 else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8, help="retry budget (rounds)")
    args = ap.parse_args()

    rows = []
    for i_dim, p, k in SHAPES:
        row = bench_shape(i_dim, p, k, args.iters)
        rows.append(row)
        print(
            f"I={i_dim:5d} P={p:5d} k={k:2d}  "
            f"reference {row['reference_s']*1e3:8.2f} ms   "
            f"pallas {row['pallas_s']*1e3:8.2f} ms   "
            f"speedup {row['speedup']:.2f}x"
        )

    payload = {
        "bench": "its_brs selection, reference vs pallas backend",
        "device": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "results": rows,
    }
    OUT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
