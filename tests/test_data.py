"""Data plane: walk corpus (C-SAW as the LM data pipeline) + graph substrate."""
import jax
import numpy as np
import pytest

from repro.data.walk_corpus import build_walk_corpus
from repro.graph import (
    csr_from_edges,
    erdos_renyi_graph,
    neighbors_padded,
    powerlaw_graph,
    rmat_graph,
)


class TestGenerators:
    def test_powerlaw_degree_distribution(self):
        g = powerlaw_graph(2048, exponent=2.2, seed=0)
        deg = np.asarray(g.indptr[1:] - g.indptr[:-1])
        # heavy tail: max degree well above mean
        assert deg.max() > 5 * deg.mean()

    def test_rmat_structure(self):
        g = rmat_graph(8, edge_factor=8, seed=1)
        assert g.num_vertices == 256
        assert g.num_edges > 0
        deg = np.asarray(g.indptr[1:] - g.indptr[:-1])
        assert deg.max() > 3 * max(deg.mean(), 1)  # skewed (community bias)

    def test_er_uniformish(self):
        g = erdos_renyi_graph(1024, avg_degree=16, seed=2)
        deg = np.asarray(g.indptr[1:] - g.indptr[:-1])
        assert abs(deg.mean() - 16) < 4

    def test_csr_sorted_and_deduped(self):
        src = np.array([0, 0, 0, 1, 1])
        dst = np.array([2, 2, 1, 0, 0])
        g = csr_from_edges(3, src, dst)
        ind = np.asarray(g.indices)
        ip = np.asarray(g.indptr)
        assert list(ind[ip[0] : ip[1]]) == [1, 2]
        assert list(ind[ip[1] : ip[2]]) == [0]

    def test_neighbors_padded(self):
        g = csr_from_edges(4, np.array([0, 0, 1]), np.array([1, 2, 3]))
        import jax.numpy as jnp
        nbrs, wts, mask = neighbors_padded(g, jnp.array([0, 1, 3]), 4)
        assert nbrs.shape == (3, 4)
        np.testing.assert_array_equal(np.asarray(mask).sum(-1), [2, 1, 0])
        assert set(np.asarray(nbrs[0][:2]).tolist()) == {1, 2}


class TestWalkCorpus:
    def test_sequences_are_graph_paths(self):
        g = powerlaw_graph(200, seed=5)
        corpus = build_walk_corpus(g, num_walks=64, walk_length=10, seed=1)
        assert corpus.shape == (64, 11)
        assert (corpus >= 0).all()
        ip, ind = np.asarray(g.indptr), np.asarray(g.indices)
        for row in corpus[:16]:
            for a, b in zip(row[:-1], row[1:]):
                if a == b:  # dead-end padding repeats last vertex
                    continue
                assert b in ind[ip[a] : ip[a + 1]]

    def test_vocab_bound(self):
        g = powerlaw_graph(200, seed=5)
        corpus = build_walk_corpus(g, num_walks=16, walk_length=5, vocab_size=256)
        assert corpus.max() < 256

    def test_node2vec_corpus(self):
        g = powerlaw_graph(128, seed=6, weighted=True)
        corpus = build_walk_corpus(
            g, num_walks=16, walk_length=8, algorithm="node2vec", p=4.0, q=0.25
        )
        assert corpus.shape == (16, 9)

    def test_feeds_lm_training(self):
        """End-to-end integration: C-SAW walks -> pipeline -> LM loss drops."""
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.data.pipeline import TokenPipeline
        from repro.models import init_params
        from repro.train.optimizer import OptConfig, opt_init
        from repro.train.train_step import make_train_step

        g = powerlaw_graph(200, seed=7)
        # a memorizable corpus: 8 fixed walks — every batch is the same 8
        # sequences, so the LM must drive the loss down within a few dozen
        # steps (a 128-walk corpus is genuinely high-entropy: next-vertex
        # conditional entropy ≈ E[log deg], unreachable in a smoke run)
        corpus = build_walk_corpus(g, num_walks=8, walk_length=16, seed=2, vocab_size=256)
        cfg = get_smoke_config("xlstm_350m")  # vocab 256
        pipe = TokenPipeline(cfg.vocab_size, 8, 16, corpus=corpus)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ocfg = OptConfig(kind="adamw", lr=3e-3, warmup_steps=2)
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt_init(ocfg, params)
        step_fn, _ = make_train_step(cfg, ocfg, mesh)
        step = jnp.zeros((), jnp.int32)
        losses = []
        for _ in range(30):
            b = pipe.next()
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt_state, step, metrics = step_fn(params, opt_state, step, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.2, losses
