"""Batched serving demo: prefill + decode with the KV/state cache.

Loads a smoke-scale model (any of the 10 assigned archs), prefills a batch
of prompts token-by-token, then decodes continuations with the jitted
serve step — same code path the decode_32k / long_500k dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-1b --tokens 32

With ``--oom`` the demo instead exercises the §V out-of-memory sampling
path end-to-end: a power-law graph partitioned into 8 contiguous vertex
ranges, walked through the device-resident frontier queues with only 2
partitions resident at a time (DESIGN.md §8).

    PYTHONPATH=src python examples/serve_batch.py --oom
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_oom_demo(args) -> None:
    """Smoke-scale out-of-memory walk: 8 partitions, 2 resident."""
    from repro.core import algorithms as alg
    from repro.core.oom import oom_random_walk
    from repro.graph import powerlaw_graph
    from repro.graph.partition import partition_by_vertex_range

    g = powerlaw_graph(8192, seed=11, weighted=True)
    parts = partition_by_vertex_range(g, 8)
    seeds = np.random.default_rng(0).integers(0, g.num_vertices, args.batch * 32)
    t0 = time.perf_counter()
    walks, stats = oom_random_walk(
        parts, g.num_vertices, seeds, jax.random.PRNGKey(0),
        depth=args.tokens // 2, spec=alg.weighted_random_walk(),
        max_degree=g.max_degree(), memory_capacity=2, chunk=256,
    )
    secs = time.perf_counter() - t0
    done = (walks >= 0).sum(axis=1)
    print(f"oom walk: {len(seeds)} instances x depth {args.tokens // 2} over "
          f"{len(parts)} partitions (2 resident) in {secs*1e3:.0f} ms")
    print(f"transfers={stats.partition_transfers} "
          f"bytes={stats.bytes_transferred} kernels={stats.kernel_launches} "
          f"sampled_edges={stats.sampled_edges} dropped={stats.frontier_dropped}")
    print(f"mean walk length: {done.mean():.1f}")
    print(f"sample walk (instance 0): {walks[0][:12].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--oom", action="store_true",
                    help="run the out-of-memory graph sampling demo instead")
    args = ap.parse_args()

    if args.oom:
        run_oom_demo(args)
        return

    from repro.configs import get_smoke_config
    from repro.models import decode_step, init_cache, init_params
    from repro.train.train_step import make_serve_step

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    max_len = args.prompt_len + args.tokens
    serve, _ = make_serve_step(cfg, mesh, batch=args.batch, max_len=max_len)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache = init_cache(cfg, args.batch, max_len)

    # prefill: feed prompt tokens through the decode path (recurrent archs
    # have O(1) state; attention archs fill the KV cache)
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = serve(params, cache, prompts[:, t : t + 1])
    prefill_s = time.perf_counter() - t0

    # decode: greedy continuation
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_s = time.perf_counter() - t0
    seqs = np.concatenate(out, axis=1)
    tput = args.batch * (args.tokens - 1) / decode_s
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_s*1e3:.0f} ms")
    print(f"decode:  {args.tokens-1} steps in {decode_s*1e3:.0f} ms ({tput:.0f} tok/s)")
    print(f"sample continuation (request 0): {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
