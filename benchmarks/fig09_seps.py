"""Paper Fig. 9: sampling throughput in Sampled Edges Per Second (SEPS).

The paper compares C-SAW vs KnightKing (biased random walk) and GraphSAINT
(MDRW).  Offline we report SEPS of this engine across graphs and selection
methods — ``updated`` doubles as the recompute-CTPS baseline the others are
measured against (paper Fig. 6(b)); ``gumbel`` is the beyond-paper mode.
Instance counts follow the paper's setup (4k walk instances / 2k sampling
instances), scaled to CPU-feasible depth.
"""
from __future__ import annotations

import jax

from benchmarks.common import BENCH_GRAPHS, row, timeit
from repro.core import algorithms as alg
from repro.core.engine import random_walk, traversal_sample


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    for gname, build in BENCH_GRAPHS.items():
        g = build()
        md = min(g.max_degree(), 512)
        # --- biased random walk (KnightKing comparison point) ---------------
        seeds = jax.random.randint(key, (4000,), 0, g.num_vertices)
        spec = alg.biased_random_walk()

        def walk():
            return random_walk(g, seeds, key, depth=64, spec=spec, max_degree=md)

        secs = timeit(walk)
        edges = int(walk().sampled_edges)
        rows.append(row(f"fig09/biased_rw/{gname}", secs * 1e6, f"SEPS={edges/secs:.3e}"))

        # --- MDRW (GraphSAINT comparison point) ------------------------------
        pools = jax.random.randint(key, (512, 8), 0, g.num_vertices)
        mspec = alg.multi_dimensional_random_walk()

        def mdrw():
            return traversal_sample(
                g, pools, key, depth=16, spec=mspec, max_degree=md, pool_capacity=16
            )

        secs = timeit(mdrw)
        edges = int(mdrw().num_edges.sum())
        rows.append(row(f"fig09/mdrw/{gname}", secs * 1e6, f"SEPS={edges/secs:.3e}"))
    return rows
