import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) cell against the production meshes and
record memory/cost/collective analysis for the roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] [--skip-existing]

Outputs one JSON per cell under results/dryrun/<mesh>/<arch>__<shape>.json.
No arrays are ever allocated: params/optimizer/cache enter as
ShapeDtypeStructs through .lower().
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import shapes as shp
from repro.launch.hlo_analysis import analyze, xla_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as m
from repro.train import optimizer as opt
from repro.train.train_step import (
    cache_specs,
    make_prefill,
    make_serve_step,
    make_train_step,
)
from repro.distributed import sharding as shd

# v5e hardware model (DESIGN.md §7)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link
HBM_CAP = 16e9  # bytes per chip


def _metric(d: dict, *names, default=0.0):
    for n in names:
        if n in d:
            return float(d[n])
    return default


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, overrides: dict | None = None) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    # launcher policy: pure-DP mode needs the global batch to fill the mesh;
    # otherwise fall back to TP (xlstm on 512 chips with batch 256 — §Perf)
    n_chips = 512 if multi_pod else 256
    if cfg.tp_mode == "dp" and shp.SHAPES[shape_name]["batch"] < n_chips:
        cfg = dataclasses.replace(cfg, tp_mode="model", microbatches=max(cfg.microbatches, 2))
    ok, why = shp.cell_applicable(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skip" if not ok else "pending",
    }
    if not ok:
        rec["skip_reason"] = why
        return _save(rec, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    sh = shp.SHAPES[shape_name]
    specs = shp.input_specs(cfg, shape_name)
    t0 = time.time()

    with mesh:
        if sh["kind"] == "train":
            ocfg = opt.OptConfig(kind=cfg.optimizer)
            step_fn, (pspecs, ospecs, _) = make_train_step(cfg, ocfg, mesh, global_batch=sh["batch"])
            params = m.abstract_params(cfg)
            opt_state = jax.eval_shape(lambda p: opt.opt_init(ocfg, p), params)
            step = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step_fn.lower(params, opt_state, step, specs)
        elif sh["kind"] == "prefill":
            fn, _ = make_prefill(cfg, mesh)
            params = m.abstract_params(cfg)
            lowered = fn.lower(params, specs)
        else:  # decode
            fn, _ = make_serve_step(cfg, mesh, batch=sh["batch"], max_len=sh["seq"])
            params = m.abstract_params(cfg)
            cache = m.abstract_cache(cfg, sh["batch"], sh["seq"])
            lowered = fn.lower(params, cache, specs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware analysis (XLA's cost_analysis counts while bodies once —
    # scanned layers would be undercounted n_rep×; see hlo_analysis.py)
    hc = analyze(hlo)
    del hlo
    coll = hc.collectives
    wires = hc.wire_bytes

    flops_dev = hc.flops
    bytes_dev = hc.bytes_accessed
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = wires / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    n_params = cfg.param_count()
    if sh["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        model_flops = 6 * cfg.active_param_count() * tokens
    elif sh["kind"] == "prefill":
        tokens = sh["batch"] * sh["seq"]
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = sh["batch"]  # one token per sequence
        model_flops = 2 * cfg.active_param_count() * tokens
    hlo_flops_total = flops_dev * chips
    useful = model_flops / hlo_flops_total if hlo_flops_total else 0.0

    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        peak_memory_bytes=int(getattr(mem, "peak_memory_in_bytes", 0)),
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
        # device-resident bytes = args (params/opt/cache) + temps − donated
        fits_hbm=bool(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
            < HBM_CAP
        ),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        xla_raw_flops=_metric(cost, "flops"),
        xla_raw_bytes=_metric(cost, "bytes accessed"),
        collectives={k: v for k, v in coll.items() if v["count"]},
        wire_bytes_per_device=wires,
        roofline=terms,
        dominant=dominant,
        model_flops=model_flops,
        useful_flop_ratio=round(useful, 4),
        tokens=tokens,
    )
    return _save(rec, out_dir)


def _save(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument(
        "--override", action="append", default=[],
        help="config override key=value (perf iterations; use with --out results/hillclimb)",
    )
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    mesh_name = "pod2x16x16" if args.multipod else "pod16x16"
    out_dir = os.path.join(args.out, mesh_name)

    if args.all:
        # one fresh subprocess per cell: bounds compile-cache/arena growth
        # and makes the sweep restartable cell-by-cell.
        for a in ARCH_IDS:
            for s in shp.SHAPES:
                cfg_name = get_config(a).name
                path = os.path.join(out_dir, f"{cfg_name}__{s}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {cfg_name} {s}", flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", args.out]
                if args.multipod:
                    cmd.append("--multipod")
                subprocess.run(cmd, check=False)
        return

    cells = []
    assert args.arch and args.shape, "--arch/--shape or --all"
    cells.append((args.arch, args.shape))

    for arch, shape_name in cells:
        cfg_name = get_config(arch).name
        path = os.path.join(out_dir, f"{cfg_name}__{shape_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip existing] {cfg_name} {shape_name}")
            continue
        print(f"[dryrun] {cfg_name} × {shape_name} × {mesh_name} {overrides or ''} ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, args.multipod, out_dir, overrides)
            if rec["status"] == "ok":
                print(
                    f"  ok: compile={rec['compile_s']}s peak={rec['peak_memory_bytes']/1e9:.2f}GB "
                    f"flops/dev={rec['flops_per_device']:.3e} dominant={rec['dominant']} "
                    f"useful={rec['useful_flop_ratio']}",
                    flush=True,
                )
                print("  memory_analysis:", {
                    "peak": rec["peak_memory_bytes"], "args": rec["argument_bytes"],
                    "temp": rec["temp_bytes"]})
                print("  cost_analysis:", {
                    "flops": rec["flops_per_device"], "bytes": rec["bytes_per_device"]})
            else:
                print(f"  SKIP: {rec.get('skip_reason')}", flush=True)
        except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
            rec = {
                "arch": cfg_name, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            _save(rec, out_dir)
            print(f"  ERROR: {e}", flush=True)


if __name__ == "__main__":
    main()
