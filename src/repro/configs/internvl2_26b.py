"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Assigned: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Backbone (InternLM2-20B-class LM) only by assignment: the InternViT
frontend is a STUB — ``input_specs()`` provides precomputed patch
embeddings projected into the LM embedding space.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    pattern=("global",),
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=256,
    optimizer="adamw",
    microbatches=4,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=("global",),
    activation="swiglu",
    glu=True,
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=8,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=16,
    remat="none",
)
