"""Multi-device sampling (paper §V-D + beyond-paper graph sharding).

Paper-faithful mode — ``instance_parallel_walk``: sampling instances are
split into equal disjoint groups across devices, the graph is replicated,
and *no* inter-device communication happens (the paper's multi-GPU design).

Beyond-paper mode — ``graph_sharded_walk``: the CSR is range-partitioned
across devices (each device owns a contiguous vertex range, HBM use scales
1/D); walker state is replicated and advanced with a per-step ``psum`` of
owner-computed successors.  This is what a 1000+ node deployment needs when
the graph exceeds a single HBM; at extreme scale the psum over walker state
would become a ragged all_to_all, which we document rather than emulate.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.api import SamplingSpec
from repro.core import select as sel
from repro.core import transition as tp
from repro.core.engine import WalkResult, _edge_ctx, random_walk
from repro.distributed.sharding import shard_map_compat
from repro.graph.csr import CSRGraph
from repro.graph.partition import PartitionMap, partition_by_vertex_range




def instance_parallel_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> WalkResult:
    """Shard instances over ``axis``; replicate the graph; zero collectives."""

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(), P(axis), P()),
        out_specs=WalkResult(P(axis), P(axis), P()),
    )
    def _run(graph, seeds, key):
        # fold in the device index so instance groups draw independent randoms
        didx = jax.lax.axis_index(axis)
        res = random_walk(graph, seeds, jax.random.fold_in(key, didx),
                          depth=depth, spec=spec, max_degree=max_degree)
        return WalkResult(res.walks, res.lengths,
                          jax.lax.psum(res.sampled_edges, axis))

    return _run(graph, seeds, key)


def shard_graph_for_mesh(graph: CSRGraph, num_devices: int):
    """Range-partition a CSR into per-device stacked local CSRs.

    Returns (indptr_stack (D, V+1), indices_stack (D, Emax), weights_stack)
    where each device's slice covers the full vertex-id space with empty rows
    for unowned vertices (so global ids index directly) and edge arrays are
    padded to the max partition size.
    """
    parts = partition_by_vertex_range(graph, num_devices)
    v = graph.num_vertices
    emax = max(p.num_edges for p in parts)
    indptrs, indices, weights = [], [], []
    for p in parts:
        full = np.zeros(v + 1, np.int32)
        full[p.vertex_lo + 1 : p.vertex_hi + 1] = p.indptr[1:]
        full[p.vertex_hi + 1 :] = p.indptr[-1]
        indptrs.append(full)
        indices.append(np.pad(p.indices, (0, emax - p.num_edges), constant_values=0).astype(np.int32))
        weights.append(np.pad(p.weights, (0, emax - p.num_edges)).astype(np.float32))
    return (
        jnp.asarray(np.stack(indptrs)),
        jnp.asarray(np.stack(indices)),
        jnp.asarray(np.stack(weights)),
    )


def graph_sharded_walk(
    mesh: Mesh,
    graph: CSRGraph,
    seeds: jax.Array,
    key: jax.Array,
    *,
    depth: int,
    spec: SamplingSpec,
    max_degree: int,
    axis: str = "data",
) -> jax.Array:
    """Walk over a device-sharded graph: owners advance, psum merges.

    Returns walks (I, depth+1).  Per step each device computes successors for
    walkers whose current vertex it owns (others contribute zeros) and a
    single integer psum replicates the advanced state.
    """
    ndev = mesh.shape[axis]
    nvert = graph.num_vertices
    program = tp.lower(spec)
    indptr_s, indices_s, weights_s = shard_graph_for_mesh(graph, ndev)
    # same cached bounds the partitioner used — lo/hi must match the shards
    bounds = PartitionMap.create(nvert, ndev).bounds.astype(np.int32)
    lo = jnp.asarray(bounds[:-1])
    hi = jnp.asarray(bounds[1:])

    @functools.partial(
        shard_map_compat,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=P(),
    )
    def _run(indptr, indices, wts, lo, hi, seeds, key):
        local = CSRGraph(indptr[0], indices[0], wts[0])
        lo0, hi0 = lo[0], hi[0]
        home = seeds.astype(jnp.int32) if program.carries_home else None

        def step(carry, it):
            cur, prev = carry
            own = (cur >= lo0) & (cur < hi0)
            safe = jnp.where(own, cur, lo0)  # in-range dummy for gathers
            ctx, mask = _edge_ctx(local, safe, prev, it, max_degree, spec.needs_prev_neighbors)
            biases = jnp.where(mask, spec.edge_bias(ctx), 0.0)
            kstep = jax.random.fold_in(key, it)  # same key on all devices
            idx = sel.select_with_replacement(jax.random.fold_in(kstep, 1), biases, mask, 1)[..., 0]
            u = jnp.take_along_axis(ctx.u, idx[..., None], axis=-1)[..., 0]
            alive = own & (cur >= 0) & jnp.any(mask, axis=-1)
            # post-select update through the lowered epilogue (shared with
            # the in-memory engines and the OOM drain, DESIGN.md §10)
            u = jnp.where(
                alive,
                tp.apply_epilogue(
                    jax.random.fold_in(kstep, 2), program, spec, ctx, u, home
                ),
                -1,
            )
            contrib = jnp.where(own, jnp.where(alive, u, -1), 0)
            dead = jax.lax.psum(jnp.where(own, jnp.where(alive, 0, 1), 0), axis)
            nxt = jax.lax.psum(contrib, axis)  # exactly one owner contributes
            nxt = jnp.where((dead > 0) | (cur < 0), -1, nxt)
            return (nxt, cur), nxt

        (_, _), path = jax.lax.scan(
            step, (seeds.astype(jnp.int32), jnp.full(seeds.shape, -1, jnp.int32)), jnp.arange(depth)
        )
        return jnp.concatenate([seeds[None].astype(jnp.int32), path], 0).T

    return _run(indptr_s, indices_s, weights_s, lo, hi, seeds, key)
